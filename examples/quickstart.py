"""Quickstart: the paper's FP4 numerics in five minutes.

  PYTHONPATH=src python examples/quickstart.py

1. Quantize a tensor to NVFP4 (block-16 E2M1 codes + E4M3 scales) with RtN
   and SR; verify SR unbiasedness.
2. Run one FQT matmul with the paper's six quantization points.
3. Train a tiny Llama for 50 steps in full FP4 and watch the §4
   gradient-to-noise monitor.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fqt
from repro.core.quantize import NVFP4, block_quantize, fake_quant

# ---- 1. NVFP4 block quantization ------------------------------------------------
x = jax.random.normal(jax.random.PRNGKey(0), (4, 64), jnp.float32)
qt = block_quantize(x, NVFP4)
print("codes (E2M1 grid):", np.unique(np.abs(np.asarray(qt.codes)))[:8])
print("scales shape:", qt.scales.shape, " tensor scale:", float(qt.tscale))
print("max |dequant - x|:", float(jnp.max(jnp.abs(qt.dequant() - x))))

# SR is unbiased: mean over draws converges to x
sr = NVFP4.with_rounding(stochastic=True)
draws = jnp.stack([fake_quant(x, sr, key=jax.random.PRNGKey(i))
                   for i in range(128)])
print("SR mean abs bias:", float(jnp.mean(jnp.abs(draws.mean(0) - x))))

# ---- 2. one FQT matmul -----------------------------------------------------------
qcfg = fqt.nvfp4_paper_config()   # paper eqs. 4-6: RtN fwd, SR bwd/update
w = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.bfloat16)
xb = x.astype(jnp.bfloat16)


def loss(w):
    y = fqt.fp4_matmul(xb, w, cfg=qcfg, seed=jnp.uint32(7))
    return jnp.sum(y.astype(jnp.float32) ** 2)


g = jax.grad(loss)(w)
print("FQT matmul grad norm:", float(jnp.linalg.norm(g.astype(jnp.float32))))

# ---- 3. 50 FP4 training steps ------------------------------------------------------
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.train import TrainConfig, init_state, make_train_step

cfg = get_config("llama2-60m").smoke()
tcfg = TrainConfig(remat=False)
data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                              global_batch=8))
state = init_state(cfg, tcfg, jax.random.PRNGKey(0))
step_fn = make_train_step(cfg, qcfg, tcfg)
for step in range(50):
    batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
    state, m = step_fn(state, batch)
    if step % 10 == 0:
        print(f"step {step:3d}  loss {float(m['loss']):.4f}  "
              f"grad-to-noise {float(m['gnr']):.1f} (switch at √3≈1.73)")
print("done — full FP4 training, loss is descending.")
