"""QAF (quantization-aware finetuning) — the paper's §5 gap-closing phase.

Pretrains a small model in full FP4, then continues with the forward pass
kept in FP4 and the backward/update GEMMs in BF16, with the paper's LR
recipe (reset + 40-step warmup + cosine).  Prints the loss gap to a BF16
baseline before and after QAF — the paper's Fig. 6b claim.

  PYTHONPATH=src python examples/qaf_finetune.py [--pretrain 150 --qaf 60]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import fqt
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw, schedule
from repro.train import TrainConfig, init_state, make_train_step


def train(cfg, qcfg, tcfg, data, state, lo, hi):
    fn = make_train_step(cfg, qcfg, tcfg)
    losses = []
    for step in range(lo, hi):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, m = fn(state, batch)
        losses.append(float(m["loss"]))
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pretrain", type=int, default=150)
    ap.add_argument("--qaf", type=int, default=60)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config("llama2-60m").smoke()
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8))
    tcfg = TrainConfig(
        opt=adamw.AdamWConfig(lr_peak=args.lr),
        sched=schedule.ScheduleConfig(peak_lr=args.lr, warmup_steps=20,
                                      total_steps=args.pretrain),
        remat=False)

    # FP4 pretrain + BF16 reference on the identical token stream
    st_fp4 = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    st_bf16 = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    st_fp4, fp4_losses = train(cfg, fqt.nvfp4_paper_config(), tcfg, data,
                               st_fp4, 0, args.pretrain)
    st_bf16, bf16_losses = train(cfg, fqt.bf16_config(), tcfg, data,
                                 st_bf16, 0, args.pretrain)
    gap0 = fp4_losses[-1] - bf16_losses[-1]

    # QAF: FP4 forward / BF16 backward, LR re-warm (paper §5)
    qaf_tcfg = TrainConfig(
        opt=tcfg.opt,
        sched=schedule.ScheduleConfig(peak_lr=args.lr * 0.5, warmup_steps=40,
                                      total_steps=args.qaf, min_lr_ratio=0.0),
        remat=False)
    st_fp4, qaf_losses = train(cfg, fqt.qaf_config(), qaf_tcfg, data,
                               st_fp4, args.pretrain,
                               args.pretrain + args.qaf)
    _, bf16_cont = train(cfg, fqt.bf16_config(), tcfg, data, st_bf16,
                         args.pretrain, args.pretrain + args.qaf)
    gap1 = qaf_losses[-1] - bf16_cont[-1]

    print(f"loss gap FP4 vs BF16 before QAF: {gap0:+.4f}")
    print(f"loss gap after {args.qaf}-step QAF: {gap1:+.4f}")
    print("deployed model remains FP4-forward-compatible "
          "(same NVFP4 RtN path as serving).")


if __name__ == "__main__":
    main()
