"""Fault tolerance demo: kill a training run mid-flight, restart, verify the
resumed run is bit-identical to an uninterrupted one.

The two pillars (DESIGN.md §6):
  * atomic step-N checkpoints (params + optimizer + threshold monitor),
  * a step-indexed data pipeline (batch = f(seed, step)) so the restart
    consumes exactly the token stream the dead run would have.

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core import fqt
from repro.data.pipeline import DataConfig
from repro.train import TrainConfig, Trainer, TrainerConfig

cfg = get_config("llama2-60m").smoke()
qcfg = fqt.nvfp4_paper_config()
tcfg = TrainConfig(remat=False)
data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=4)
tmp = tempfile.mkdtemp(prefix="fp4_ft_")

# ---- run A: 40 uninterrupted steps -----------------------------------------
run_a = Trainer(cfg, qcfg, tcfg,
                TrainerConfig(total_steps=40, ckpt_every=1000,
                              ckpt_dir=None), data_cfg)
state_a = run_a.run(jax.random.PRNGKey(0))

# ---- run B: 20 steps, "crash", restart to 40 --------------------------------
ck = f"{tmp}/ckpt"
run_b1 = Trainer(cfg, qcfg, tcfg,
                 TrainerConfig(total_steps=20, ckpt_every=20, ckpt_dir=ck),
                 data_cfg)
run_b1.run(jax.random.PRNGKey(0))
print("simulated crash after step 20; restarting from checkpoint...")

run_b2 = Trainer(cfg, qcfg, tcfg,
                 TrainerConfig(total_steps=40, ckpt_every=20, ckpt_dir=ck),
                 data_cfg)
state_b = run_b2.run(jax.random.PRNGKey(0))
assert run_b2.events and run_b2.events[0]["kind"] == "restore"

# ---- bit-identical? -----------------------------------------------------------
diffs = [float(np.max(np.abs(np.asarray(a, np.float32)
                             - np.asarray(b, np.float32))))
         for a, b in zip(jax.tree.leaves(state_a.params),
                         jax.tree.leaves(state_b.params))]
print(f"restored-run loss {run_b2.history[-1]['loss']:.6f} vs "
      f"uninterrupted {run_a.history[-1]['loss']:.6f}")
print(f"max param diff after resume: {max(diffs):.2e}")
assert max(diffs) == 0.0, "resume must be bit-identical (SR seeds are " \
    "step-indexed and the checkpoint carries fp32 masters)"
print("OK: killed-and-restarted run is bit-identical to the straight run.")
shutil.rmtree(tmp)
