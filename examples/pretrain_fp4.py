"""End-to-end driver: pretrain a ~100M-param Llama in full FP4 (NVFP4 FQT)
for a few hundred steps, against a BF16 reference — the paper's Fig. 6a at
example scale — with checkpointing and automatic QAF switching.

  PYTHONPATH=src python examples/pretrain_fp4.py [--steps 300] [--d-model 512]

The model here is the paper's own family (llama2 architecture: RMSNorm,
smooth-SwiGLU, RoPE) at ~100M params: 12 layers × d_model 512 with a 8k
synthetic vocab.  Takes ~20-40 min on CPU; pass --steps 60 for a smoke run.
"""
import argparse
import dataclasses
import json
import os

import jax

from repro.configs import get_config
from repro.core import fqt, qaf
from repro.data.pipeline import DataConfig
from repro.optim import adamw, schedule
from repro.train import TrainConfig, Trainer, TrainerConfig


def build_cfg(d_model: int):
    base = get_config("llama2-350m")
    return dataclasses.replace(
        base, name="llama2-100m", n_layers=12, d_model=d_model,
        n_heads=8, n_kv_heads=8, head_dim=d_model // 8, d_ff=4 * d_model,
        vocab_size=8192, attn_chunk=256)


def run(tag: str, qcfg, cfg, args, ckpt_dir):
    tcfg = TrainConfig(
        opt=adamw.AdamWConfig(lr_peak=args.lr),
        sched=schedule.ScheduleConfig(peak_lr=args.lr, warmup_steps=40,
                                      total_steps=args.steps),
        remat=False)
    run_cfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=max(50, args.steps // 4),
        ckpt_dir=os.path.join(ckpt_dir, tag),
        qaf=qaf.QAFConfig(enabled=(tag == "fp4"), auto_switch=False,
                          fixed_switch_step=int(args.steps * 0.8)))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    tr = Trainer(cfg, qcfg, tcfg, run_cfg, data_cfg)
    tr.run(jax.random.PRNGKey(0))
    print(f"[{tag}] final loss {tr.history[-1]['loss']:.4f}  "
          f"events: {[e['kind'] for e in tr.events]}")
    return tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/fp4_pretrain")
    args = ap.parse_args()

    cfg = build_cfg(args.d_model)
    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: __import__("repro.models.registry",
                                          fromlist=["x"]).init_params(
                                              cfg, jax.random.PRNGKey(0)))))
    print(f"model: {cfg.name}  params ≈ {n/1e6:.0f}M")

    fp4 = run("fp4", fqt.nvfp4_paper_config(), cfg, args, args.ckpt_dir)
    bf16 = run("bf16", fqt.bf16_config(), cfg, args, args.ckpt_dir)

    gap = fp4.history[-1]["loss"] - bf16.history[-1]["loss"]
    print(f"\nFP4-vs-BF16 final-loss gap: {gap:+.4f} "
          f"(paper: small gap, closed by QAF — see the qaf_switch event)")
    with open(os.path.join(args.ckpt_dir, "curves.json"), "w") as f:
        json.dump({"fp4": [h["loss"] for h in fp4.history],
                   "bf16": [h["loss"] for h in bf16.history]}, f)
    print(f"loss curves -> {args.ckpt_dir}/curves.json")


if __name__ == "__main__":
    main()
