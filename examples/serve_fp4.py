"""Batched FP4 serving: prefill + decode through the Engine.

Serves a reduced tinyllama with the NVFP4 forward path (the deployed
numeric configuration the paper's QAF phase preserves).  The engine packs
every GEMM weight ONCE into 4-bit NVFP4 storage at build (uint8 nibble
codes + float8 block scales, ~0.56 bytes/param) — the decode loop streams
packed weights instead of re-fake-quantizing bf16 each token, and the
tokens are bit-identical to the fake-quant forward.  The KV cache is
likewise stored block-quantized (``ServeConfig.kv_cache_format``,
"nvfp4" by default: 0.5625 bytes/elem vs 2 for bf16), so long-context
decode attention streams ~3.56x less cache from HBM.  Compares greedy
outputs against a bf16-forward engine and reports decode throughput plus
the weight-store and KV-cache footprints.

  PYTHONPATH=src python examples/serve_fp4.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import fqt
from repro.core.quantize import kv_bytes_per_elem
from repro.models import registry
from repro.serve import (ContinuousEngine, Engine, Request, ServeConfig,
                         weight_store_bytes)

cfg = get_config("tinyllama-1.1b").smoke()
params = registry.init_params(cfg, jax.random.PRNGKey(0))
scfg = ServeConfig(batch_size=4, max_len=128, temperature=0.0,
                   kv_cache_format="nvfp4")   # "fp8" | "bf16" escape hatch

rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, 16) for _ in range(4)]

fp4 = Engine(cfg, params, scfg)          # NVFP4 forward, packed-once weights
bf16 = Engine(cfg, params, scfg, qcfg=fqt.bf16_config())

mb = 1024 * 1024
print(f"weight store: bf16 {weight_store_bytes(bf16.params)/mb:.2f} MiB -> "
      f"packed NVFP4 {weight_store_bytes(fp4.params)/mb:.2f} MiB "
      f"({weight_store_bytes(bf16.params)/weight_store_bytes(fp4.params):.2f}"
      "x less decode HBM traffic)")


# K + V elements per cached token across the stack
kv_elems = 2 * cfg.n_kv_heads * cfg.hd * cfg.n_layers
bpt = {f: kv_bytes_per_elem(f) * kv_elems for f in ("bf16", "nvfp4", "fp8")}
print(f"KV cache: bf16 {bpt['bf16']:.0f} B/token -> "
      f"{scfg.kv_cache_format} {bpt[scfg.kv_cache_format]:.0f} B/token "
      f"({bpt['bf16'] / bpt[scfg.kv_cache_format]:.2f}"
      "x less decode-attention HBM traffic)")

t0 = time.perf_counter()
out_fp4 = fp4.generate(prompts, max_new=24)
t_fp4 = time.perf_counter() - t0
out_bf16 = bf16.generate(prompts, max_new=24)

agree = np.mean([
    np.mean(a[: min(len(a), len(b))] == b[: min(len(a), len(b))])
    for a, b in zip(out_fp4, out_bf16)])
print(f"FP4 decode: {sum(map(len, out_fp4))} tokens in {t_fp4:.2f}s "
      f"(incl. compile)")
print(f"greedy agreement FP4 vs BF16 forward: {agree:.2f} "
      "(untrained weights — quantization flips low-margin argmaxes; "
      "trained+QAF models are tuned to the FP4 grid)")
for i, o in enumerate(out_fp4[:2]):
    print(f"seq {i}: {o[:12].tolist()}")

# ---- continuous batching: a request QUEUE over a paged NVFP4 KV cache --------
# Eight staggered requests stream through four decode slots: the scheduler
# admits from its FIFO queue whenever a slot AND enough KV pages are free,
# slots are reused as requests hit max_new, and the device side stays on
# exactly two compiled programs (prefill-into-slot, batched decode).
ce = ContinuousEngine(cfg, params, ServeConfig(
    max_slots=4, batch_size=4, max_len=128, page_size=16,
    kv_cache_format="nvfp4"))
queue = [Request(rid=i,
                 prompt=rng.integers(0, cfg.vocab_size, 8 + (i % 3) * 4),
                 max_new=10 + (i % 4) * 4,
                 arrival=i // 3)            # tick-indexed: deterministic
         for i in range(8)]
t0 = time.perf_counter()
results = ce.run(queue)
dt = time.perf_counter() - t0
ntok = sum(map(len, results.values()))
print(f"continuous batching: {ntok} tokens / {len(results)} requests in "
      f"{dt:.2f}s (slot utilization "
      f"{ce.scheduler.slot_utilization:.2f}; compiles: prefill "
      f"{ce.prefill_compiles}, decode {ce.decode_compiles})")
for rid in sorted(results)[:2]:
    print(f"req {rid}: {results[rid][:12].tolist()}")

# ---- exact shared-prefix cache: warm prompts skip their prefill --------------
# Chat traffic repeats system prompts.  With prefix_cache=True the scheduler
# keeps a radix tree over full-page token chunks: later requests point their
# page tables at the SHARED physical pages (refcounted) and prefill only the
# suffix.  RtN page quantization is deterministic, so sharing is exact — the
# warm requests' tokens are bit-identical to cold starts of the same prompts.
pc = ContinuousEngine(cfg, params, ServeConfig(
    max_slots=4, batch_size=4, max_len=128, page_size=16,
    kv_cache_format="nvfp4", prefix_cache=True, prefix_cache_pages=64))
system = rng.integers(0, cfg.vocab_size, 40)          # the shared prefix
chats = [Request(rid=i,
                 prompt=np.concatenate(
                     [system, rng.integers(0, cfg.vocab_size, 4 + i)]),
                 max_new=8, arrival=i // 2)
         for i in range(6)]
warm = pc.run(chats)
st = pc.scheduler.stats
print(f"prefix cache: hit rate {pc.scheduler.prefix_hit_rate:.2f}, "
      f"{st['prefix_tokens_skipped']} prefill tokens skipped "
      f"({st['prefilled_tokens']} prefilled), pages {st['shared_pages']} "
      f"shared / {st['private_pages']} private / {st['demand_pages']} "
      f"on-demand")
# the cache PERSISTS across run() traces on the same engine — a fresh
# ENGINE is the genuinely cold baseline; sharing is exact, so warm == cold
cold_eng = ContinuousEngine(cfg, params, pc.scfg)
cold = cold_eng.run([chats[5]])
print(f"warm == cold start, bit-exact: "
      f"{np.array_equal(warm[5], cold[5])}")
rerun = pc.run([chats[5]])                # SAME engine: prefix still hot
print(f"cache persists across traces: hit rate "
      f"{pc.scheduler.prefix_hit_rate:.2f}, rerun bit-exact: "
      f"{np.array_equal(warm[5], rerun[5])}")

# ---- multi-tenant traffic: chunked prefill + lifecycle + tick metrics --------
# A seeded workload (serve/workload.py): two tenants with their own Poisson
# arrival rates, prompt-length mixes and shared system prompts, plus abort/
# timeout events.  prefill_chunk=16 streams long prompts into their slots 16
# tokens per tick, interleaved with decode (bit-exact — chunks attend through
# the quantized pages), so a long prompt never stalls a decode tick by more
# than one chunk.  serve/metrics.py records TTFT/TPOT/goodput in simulated
# ticks — deterministic, no wall clock.
from repro.serve import TenantSpec, WorkloadConfig, as_requests, \
    generate_workload

wl = WorkloadConfig(tenants=(
    TenantSpec("chat", rate=0.5, prompt_lens=(8, 16), system_prompt_len=32,
               max_new=10, deadline_slack=24),
    TenantSpec("batch", rate=0.2, prompt_lens=(48,), max_new=6,
               abort_prob=0.2, abort_after=4, timeout=40),
), ticks=16, seed=3, vocab=cfg.vocab_size)
mt = ContinuousEngine(cfg, params, ServeConfig(
    max_slots=4, batch_size=4, max_len=128, page_size=16,
    kv_cache_format="nvfp4", prefix_cache=True, prefill_chunk=16))
mt.run(as_requests(generate_workload(wl)))
ms = mt.metrics.summary()
print(f"traffic: {ms['completed']}/{ms['submitted']} done, "
      f"{ms['cancelled']} cancelled, goodput {ms['goodput']:.2f}; "
      f"TTFT p50/p95 {ms['ttft_ticks']['p50']:.0f}/"
      f"{ms['ttft_ticks']['p95']:.0f} ticks, TPOT p50 "
      f"{ms['tpot_ticks']['p50']:.2f}; "
      f"{len(mt.scheduler.prefill_log)} prefill chunks "
      f"(<= 16 tok/slot/tick), compiles "
      f"{mt.chunk_compiles}+{mt.prefill_suffix_compiles}+"
      f"{mt.decode_compiles}")

# ---- speculative decoding: self-draft, verify-k, exact rollback --------------
# spec_k=3 turns each decode tick into: a shallow self-draft (the first
# draft_layers layers of the SAME packed weights — zero extra HBM) proposes
# 2 tokens per slot, ONE teacher-forced verify pass checks the block, and
# the paged cache rolls rejected rows back exactly (truncate_to) — 1..3
# tokens committed per slot per tick.  Greedy acceptance is exact argmax
# agreement, so the streams are BIT-identical to sequential decode: the
# acceptance rate moves throughput, never tokens.
sp = ContinuousEngine(cfg, params, ServeConfig(
    max_slots=4, batch_size=4, max_len=128, page_size=16,
    kv_cache_format="nvfp4", spec_k=3, draft_layers=1))
spec_res = sp.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                           arrival=r.arrival) for r in queue])
sms = sp.metrics.summary()
acc = sms["spec_accepted_per_tick_slot"]
print(f"speculative (k=3, draft {sp.draft_layers}/{cfg.n_layers} layers): "
      f"{acc['mean']:.2f} accepted tokens/tick/slot (p95 {acc['p95']:.0f}), "
      f"acceptance rate {sms['spec_acceptance_rate']['mean']:.2f}; "
      f"compiles: verify {sp.verify_compiles}, decode {sp.decode_compiles}")
print(f"speculative == sequential, bit-exact: "
      f"{all(np.array_equal(spec_res[r], results[r]) for r in results)}")

# ---- observability: trace the multi-tenant run, bit-identically --------------
# A Tracer (repro.obs) records the serve lifecycle on the SIMULATED tick
# clock: one span per request (submit -> done/cancelled), per-tick engine
# spans with jit-compile instants, page/prefix-cache counters, first-token
# marks.  Emission is host-side only (fp4lint's obs-in-jit rule enforces
# it), so the traced run's tokens are bit-identical to the untraced run
# above — tracing changes nothing but what you can see.
from repro.obs import Tracer

trc = Tracer(clock="tick", process="serve_fp4")
traced = ContinuousEngine(cfg, params, ServeConfig(
    max_slots=4, batch_size=4, max_len=128, page_size=16,
    kv_cache_format="nvfp4", prefix_cache=True, prefill_chunk=16),
    tracer=trc)
traced_res = traced.run(as_requests(generate_workload(wl)))
trace_path = "/tmp/serve_fp4_trace.json"
trc.export(trace_path)
same = all(np.array_equal(traced_res[r], mt.scheduler.results[r])
           for r in traced_res)
print(f"traced rerun bit-identical to untraced: {same}")
print(f"trace: {trc.n_events} events, {trc.spans_opened} spans "
      f"({len(trc.open_spans())} unclosed), counters "
      f"{sorted(trc.counters)[:4]}... -> {trace_path} "
      f"(open in Perfetto: ui.perfetto.dev)")
