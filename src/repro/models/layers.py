"""Shared neural building blocks (pure JAX, FQT-quantized linears).

Every matmul-bearing layer routes through ``QCtx.dense`` -> fp4_matmul, so
the paper's six quantization points apply uniformly across the zoo.  The
attention *score/value* batched matmuls stay in bf16 (the paper's scope is
the three weight GEMMs; same choice as the FP8 FQT line of work — DESIGN.md
§5).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fqt
from repro.core.fqt import QuantConfig
from repro.distributed.sharding import constrain

# A large-but-finite mask value: keeps fully-masked rows NaN-free without
# inf-inf arithmetic anywhere.
NEG_INF = -1e30


class QCtx:
    """Quantization context: static QuantConfig + per-call SR seed stream.

    A fresh QCtx is created per (layer, step); each ``dense`` call gets a
    distinct deterministic seed (trace-time counter — stable across jit).
    """

    def __init__(self, qcfg: QuantConfig, seed: jax.Array):
        self.qcfg = qcfg
        self.seed = jnp.asarray(seed, jnp.uint32)
        self._n = 0

    def fold(self, idx) -> "QCtx":
        """Child context for layer/expert ``idx`` (idx may be traced)."""
        mixed = self.seed + jnp.asarray(idx, jnp.uint32) * jnp.uint32(2654435761)
        return QCtx(self.qcfg, mixed)

    def dense(self, x: jax.Array, w: jax.Array,
              b: Optional[jax.Array] = None) -> jax.Array:
        s = self.seed + jnp.uint32(self._n * 40503)
        self._n += 1
        return fqt.dense(x, w, b, cfg=self.qcfg, seed=s)

    def dense_hp(self, x: jax.Array, w: jax.Array,
                 b: Optional[jax.Array] = None) -> jax.Array:
        """High-precision (bf16) dense — routers, gates (never quantized)."""
        y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
        if b is not None:
            y = y + b
        return y.astype(x.dtype)


# ---- initializers ------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ---- norms / activations ------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)).astype(x.dtype)) * w


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def smooth_swiglu(gate: jax.Array, up: jax.Array,
                  smooth: jax.Array) -> jax.Array:
    """Smooth-SwiGLU [Fishman et al. 2024]: per-channel smoothing factor
    migrates outlier scale out of the quantized down-projection input,
    preventing the late-training FP8/FP4 instability of SwiGLU.  The factor
    is divided out of ``up`` before the product and multiplied back after
    the down projection (caller applies ``smooth`` inverse on the output
    side), so the function is numerically equivalent in high precision.
    """
    z = jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype)
    return z * (up / smooth)


# ---- rotary embeddings ---------------------------------------------------------


def rope_tables(positions: jax.Array, head_dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for given absolute positions: (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---- chunked (flash-style) attention -------------------------------------------


def _attn_dense(q, k, v, qpos, kpos, causal, window):
    """Reference dense-softmax attention for short sequences.

    q: (B, Sq, KVH, G, D); k/v: (B, Sk, KVH, D); *pos: (Sq,)/(Sk,) absolute.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o


def _flash_mask(qpch, kp, causal, window, nq, qc, kc):
    mask = jnp.ones((nq, qc, kc), bool)
    if causal:
        mask &= kp[None, None, :] <= qpch[:, :, None]
    if window is not None:
        mask &= kp[None, None, :] > qpch[:, :, None] - window
    return mask[None, :, None, None, :, :]       # broadcast to (B,nq,h,g,q,k)


def _flash_fwd_impl(q, k, v, qpos, kpos, causal, window, qc, kc):
    """Flash forward: q blocks are a PARALLEL leading dim, kv chunks a
    sequential scan with running (max, denom, acc).

    Keeping the q-block dim parallel (instead of the classic outer scan)
    exposes it to GSPMD: when the head count does not divide the TP degree
    (qwen2.5: 40 heads on a 16-way "model" axis; whisper: 8) the q-block
    dim shards on "model" instead — context-parallel attention.  The
    ``constrain(..., "qblocks")`` rule picks whichever of (heads, q-blocks)
    divides.  Returns (out, m, l) blocked as (B, nq, qc, KVH, G, ·).
    """
    from repro.distributed.sharding import constrain
    B, Sq, KVH, G, D = q.shape
    Sk = k.shape[1]
    nq, nk = Sq // qc, Sk // kc
    scale = D ** -0.5

    qch = constrain(q.reshape(B, nq, qc, KVH, G, D), "qblocks")
    qpch = qpos.reshape(nq, qc)
    kch = k.reshape(B, nk, kc, KVH, D).swapaxes(0, 1)        # (nk, B, kc, ...)
    vch = v.reshape(B, nk, kc, KVH, D).swapaxes(0, 1)
    kpch = kpos.reshape(nk, kc)
    # dots take the native (bf16) inputs with f32 accumulation: full MXU
    # rate and half the operand traffic; softmax stats stay f32.
    def kv_step(carry, kin):
        m, l, acc = carry                                    # (B,nq,KVH,G,qc)
        ki, vi, kp = kin
        s = jnp.einsum("bnqhgd,bkhd->bnhgqk", qch, ki,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_flash_mask(qpch, kp, causal, window, nq, qc, kc),
                      s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bnhgqk,bkhd->bnqhgd", p.astype(ki.dtype), vi,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr.transpose(0, 1, 4, 2, 3)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nq, KVH, G, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, KVH, G, qc), jnp.float32)
    a0 = jnp.zeros((B, nq, qc, KVH, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kch, vch, kpch))
    denom = jnp.maximum(l, 1e-30).transpose(0, 1, 4, 2, 3)[..., None]
    return acc / denom, m, l


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _attn_flash(q, k, v, qpos, kpos, causal, window, qc, kc):
    """custom_vjp flash attention.

    Plain autodiff of the kv scan stacks its (m, l, acc) carries per step —
    ~2 GiB × layers × chunks of dynamic-update-slice traffic at the 7B
    train cell (EXPERIMENTS.md §Perf).  The custom backward recomputes
    s/p per kv chunk from the saved (q, k, v, out, m, l) instead — the
    standard flash-attention backward, O(B·S·H·D) residuals.
    """
    out, _, _ = _flash_fwd_impl(q, k, v, qpos, kpos, causal, window, qc, kc)
    B, Sq, KVH, G, D = q.shape
    return out.reshape(B, Sq, KVH, G, D)


def _flash_fwd_rule(q, k, v, qpos, kpos, causal, window, qc, kc):
    out, m, l = _flash_fwd_impl(q, k, v, qpos, kpos, causal, window, qc, kc)
    B, Sq, KVH, G, D = q.shape
    return (out.reshape(B, Sq, KVH, G, D),
            (q, k, v, qpos, kpos, out, m, l))


def _flash_bwd_rule(causal, window, qc, kc, res, dout):
    q, k, v, qpos, kpos, out, m, l = res
    B, Sq, KVH, G, D = q.shape
    Sk = k.shape[1]
    nq, nk = Sq // qc, Sk // kc
    scale = D ** -0.5

    qch = q.reshape(B, nq, qc, KVH, G, D)
    qpch = qpos.reshape(nq, qc)
    kch = k.reshape(B, nk, kc, KVH, D).swapaxes(0, 1)
    vch = v.reshape(B, nk, kc, KVH, D).swapaxes(0, 1)
    kpch = kpos.reshape(nk, kc)
    do = dout.reshape(B, nq, qc, KVH, G, D).astype(jnp.float32)
    l_safe = jnp.maximum(l, 1e-30)                           # (B,nq,h,g,qc)
    # D_i = rowsum(dout * out)
    Dsum = jnp.sum(do * out, axis=-1)                        # (B,nq,qc,h,g)
    Dsum = Dsum.transpose(0, 1, 3, 4, 2)                     # (B,nq,h,g,qc)

    dob = do.astype(q.dtype)

    def kv_step(dq, kin):
        ki, vi, kp = kin
        s = jnp.einsum("bnqhgd,bkhd->bnhgqk", qch, ki,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(_flash_mask(qpch, kp, causal, window, nq, qc, kc),
                      s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / l_safe[..., None]    # (B,nq,h,g,q,k)
        pb = p.astype(q.dtype)
        dv = jnp.einsum("bnhgqk,bnqhgd->bkhd", pb, dob,
                        preferred_element_type=jnp.float32)
        dp = jnp.einsum("bnqhgd,bkhd->bnhgqk", dob, vi,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - Dsum[..., None]) * scale
        dsb = ds.astype(q.dtype)
        dq = dq + jnp.einsum("bnhgqk,bkhd->bnqhgd", dsb, ki,
                             preferred_element_type=jnp.float32)
        dk = jnp.einsum("bnhgqk,bnqhgd->bkhd", dsb, qch,
                        preferred_element_type=jnp.float32)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, nq, qc, KVH, G, D), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(kv_step, dq0, (kch, vch, kpch))
    dq = dq.reshape(B, Sq, KVH, G, D).astype(q.dtype)
    dk = dk.swapaxes(0, 1).reshape(B, Sk, KVH, D).astype(k.dtype)
    dv = dv.swapaxes(0, 1).reshape(B, Sk, KVH, D).astype(v.dtype)
    zero_pos = np.zeros(qpos.shape, dtype=jax.dtypes.float0)
    zero_kpos = np.zeros(kpos.shape, dtype=jax.dtypes.float0)
    return dq, dk, dv, zero_pos, zero_kpos


_attn_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def attention_core(q, k, v, *, qpos, kpos, causal=True,
                   window: Optional[int] = None, chunk: int = 1024,
                   kv_len: Optional[jax.Array] = None) -> jax.Array:
    """GQA attention.  q: (B,Sq,H,D), k/v: (B,Sk,KVH,D).

    ``kv_len``: optional dynamic valid-length of k/v (decode with a
    pre-allocated cache) — positions >= kv_len are masked via kpos trick.
    """
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, Sq, KVH, G, D)
    if kv_len is not None:
        # invalidate unwritten cache slots by pushing their kpos above any qpos
        kpos = jnp.where(jnp.arange(k.shape[1]) < kv_len, kpos,
                         jnp.int32(2 ** 30))
    if Sq * k.shape[1] <= chunk * chunk or Sq % min(chunk, Sq) != 0 \
            or k.shape[1] % chunk != 0:
        o = _attn_dense(qg, k, v, qpos, kpos, causal, window)
    else:
        qc = min(chunk, Sq)
        o = _attn_flash(qg, k, v, qpos, kpos, causal, window, qc, chunk)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


# ---- attention layer (projections + rope + cache) -------------------------------


def attn_params(key, d_model: int, n_heads: int, n_kv: int, hd: int,
                bias: bool = False, dtype=jnp.bfloat16, qk_norm=False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * hd, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * hd, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * hd, dtype),
        "wo": dense_init(ks[3], n_heads * hd, d_model, dtype),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((n_kv * hd,), dtype)
        p["bv"] = jnp.zeros((n_kv * hd,), dtype)
    if qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


@dataclasses.dataclass
class KVCache:
    """Per-layer KV cache.  For SWA the buffer is a rolling window."""
    k: jax.Array          # (B, S_buf, KVH, D)
    v: jax.Array
    length: jax.Array     # scalar int32: tokens written so far

    @staticmethod
    def init(batch: int, buf: int, n_kv: int, hd: int, dtype=jnp.bfloat16):
        z = jnp.zeros((batch, buf, n_kv, hd), dtype)
        return KVCache(z, jnp.zeros_like(z), jnp.zeros((), jnp.int32))


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "length"], meta_fields=[])


@dataclasses.dataclass
class PackedKVCache:
    """Block-quantized per-layer KV cache (serving decode path).

    K/V rows are quantized along the head dim at write time
    (core/quantize.kv_quant_rows, RtN) and stored packed — uint8 E2M1
    nibble pairs + float8 block scales for ``fmt="nvfp4"`` (0.5625
    bytes/elem vs 2 for bf16), float8 codes + bf16 block scales for
    ``fmt="fp8"`` (1.125 bytes/elem).  Decode attention dequantizes
    blocks on the fly (``_attn_decode_packed`` / kernels.flash_attn's
    packed kernel) so the bf16 cache is never materialized in HBM.
    Same write semantics as ``KVCache`` (linear or SWA rolling buffer).
    """

    k_codes: jax.Array    # (B, S_buf, KVH, D/2) u8  | (B, S_buf, KVH, D) f8
    k_scales: jax.Array   # (B, S_buf, KVH, D/block) f8e4m3 | bf16
    v_codes: jax.Array
    v_scales: jax.Array
    length: jax.Array     # scalar int32: tokens written so far
    fmt: str = "nvfp4"
    block: int = 16

    @staticmethod
    def init(batch: int, buf: int, n_kv: int, hd: int, fmt: str = "nvfp4",
             block: int = 16) -> "PackedKVCache":
        if hd % block or hd % 2:
            raise ValueError(
                f"packed KV cache needs head_dim divisible by block={block} "
                f"(and even), got head_dim={hd}")
        if fmt == "nvfp4":
            codes = jnp.zeros((batch, buf, n_kv, hd // 2), jnp.uint8)
            scales = jnp.ones((batch, buf, n_kv, hd // block),
                              jnp.float8_e4m3fn)
        elif fmt == "fp8":
            codes = jnp.zeros((batch, buf, n_kv, hd), jnp.float8_e4m3fn)
            scales = jnp.ones((batch, buf, n_kv, hd // block), jnp.bfloat16)
        else:
            raise ValueError(f"unknown packed KV format {fmt!r}")
        return PackedKVCache(codes, scales, jnp.copy(codes),
                             jnp.copy(scales), jnp.zeros((), jnp.int32),
                             fmt, block)

    def dequant(self, dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
        """Full-cache (k, v) reconstruction — test oracle / fallback path."""
        from repro.core.quantize import kv_dequant
        return (kv_dequant(self.k_codes, self.k_scales, self.fmt,
                           self.block, dtype),
                kv_dequant(self.v_codes, self.v_scales, self.fmt,
                           self.block, dtype))

    def nbytes(self) -> int:
        """Stored cache bytes (codes + scales, k and v)."""
        return int(sum(a.size * a.dtype.itemsize for a in
                       (self.k_codes, self.k_scales,
                        self.v_codes, self.v_scales)))


jax.tree_util.register_dataclass(
    PackedKVCache,
    data_fields=["k_codes", "k_scales", "v_codes", "v_scales", "length"],
    meta_fields=["fmt", "block"])


def _kv_quant_any(x: jax.Array, fmt: str, block: int):
    """``kv_quant_rows`` plus the bf16 passthrough (codes = values, scales
    a (..., 1) placeholder) so paged caches treat all formats uniformly."""
    if fmt == "bf16":
        return (x.astype(jnp.bfloat16),
                jnp.ones(x.shape[:-1] + (1,), jnp.bfloat16))
    from repro.core.quantize import kv_quant_rows
    return kv_quant_rows(x, fmt, block)


def _kv_dequant_any(codes: jax.Array, scales: jax.Array, fmt: str,
                    block: int, dtype=jnp.bfloat16) -> jax.Array:
    if fmt == "bf16":
        return codes.astype(dtype)
    from repro.core.quantize import kv_dequant
    return kv_dequant(codes, scales, fmt, block, dtype)


# Physical page 0 is reserved as the TRASH page: freed slots' page-table
# rows point at it, so the static-shape decode program can keep writing for
# inactive slots without corrupting pages reallocated to other requests.
TRASH_PAGE = 0


@dataclasses.dataclass
class PagedKVCache:
    """Paged per-layer KV cache: pages allocated from a shared pool.

    vLLM-style continuous batching needs per-slot sequence lengths and
    block-granular storage reuse; this container provides both on top of
    the existing packed row formats:

      * ``k_codes``/``v_codes``: the PHYSICAL page pool, (P, page, KVH, Dc)
        where Dc follows ``fmt`` (nvfp4: D/2 uint8 nibble pairs, fp8: D
        float8 codes, bf16: D bf16 — the escape hatch);
      * ``k_scales``/``v_scales``: per-row block scales, (P, page, KVH, nb);
      * ``page_table``: (B, n_pages_slot) int32 physical page per logical
        page of each slot.  Rows of freed slots point at the reserved
        ``TRASH_PAGE`` so inactive slots' decode writes land harmlessly;
      * ``lengths``: (B,) int32 tokens written per slot — the per-slot
        ``kv_len``/``q_offset`` of continuous batching.

    The logical per-slot buffer is ``n_pages_slot * page_size`` tokens.
    SWA reuses the rolling-write rule of ``KVCache`` on the LOGICAL index
    (``pos % buf``), which then maps through the page table — the rolling
    buffer migrates onto pages instead of being special-cased again.
    """

    k_codes: jax.Array    # (P, page, KVH, Dc) physical pool
    k_scales: jax.Array   # (P, page, KVH, nb)
    v_codes: jax.Array
    v_scales: jax.Array
    page_table: jax.Array  # (B, n_pages_slot) int32
    lengths: jax.Array     # (B,) int32 per-slot tokens written
    fmt: str = "nvfp4"
    block: int = 16
    page_size: int = 16

    # KV-heads axis of every pool leaf (codes AND scales), also after
    # vmap stacks a leading layer dim — the axis tensor-parallel serving
    # shards over "model" (distributed/sharding.serve_cache_shardings):
    # each device holds the pages of exactly the heads it attends with.
    HEADS_AXIS = -2

    @property
    def n_slots(self) -> int:
        return self.page_table.shape[0]

    @property
    def buf(self) -> int:
        """Logical per-slot capacity in tokens."""
        return self.page_table.shape[1] * self.page_size

    @staticmethod
    def init(slots: int, buf: int, n_kv: int, hd: int, fmt: str = "nvfp4",
             block: int = 16, page_size: int = 16,
             total_pages: Optional[int] = None) -> "PagedKVCache":
        if buf % page_size:
            raise ValueError(f"slot buffer {buf} not a multiple of "
                             f"page_size {page_size}")
        if fmt in ("nvfp4", "fp8") and (hd % block or hd % 2):
            raise ValueError(
                f"packed KV cache needs head_dim divisible by block={block} "
                f"(and even), got head_dim={hd}")
        n_pages_slot = buf // page_size
        if total_pages is None:
            total_pages = 1 + slots * n_pages_slot    # +1: the trash page
        if fmt == "nvfp4":
            codes = jnp.zeros((total_pages, page_size, n_kv, hd // 2),
                              jnp.uint8)
            scales = jnp.ones((total_pages, page_size, n_kv, hd // block),
                              jnp.float8_e4m3fn)
        elif fmt == "fp8":
            codes = jnp.zeros((total_pages, page_size, n_kv, hd),
                              jnp.float8_e4m3fn)
            scales = jnp.ones((total_pages, page_size, n_kv, hd // block),
                              jnp.bfloat16)
        elif fmt == "bf16":
            codes = jnp.zeros((total_pages, page_size, n_kv, hd),
                              jnp.bfloat16)
            scales = jnp.ones((total_pages, page_size, n_kv, 1),
                              jnp.bfloat16)
        else:
            raise ValueError(f"unknown paged KV format {fmt!r}")
        return PagedKVCache(
            codes, scales, jnp.copy(codes), jnp.copy(scales),
            jnp.full((slots, n_pages_slot), TRASH_PAGE, jnp.int32),
            jnp.zeros((slots,), jnp.int32), fmt, block, page_size)

    # ---- writes ---------------------------------------------------------

    def write_prompt(self, slot, k: jax.Array, v: jax.Array,
                     plen) -> "PagedKVCache":
        """Prefill-into-slot: write a fresh (1, Sp, KVH, D) sequence into
        ``slot``'s pages at logical positions [0, Sp) and reset the slot's
        length to ``plen`` (the true prompt length; rows in [plen, Sp) are
        right-pad garbage masked out by ``lengths`` at read time).
        ``Sp <= buf`` so logical indices never collide (static check)."""
        if k.shape[1] > self.buf:
            raise ValueError(f"prefill length {k.shape[1]} exceeds slot "
                             f"capacity {self.buf}")
        return self.write_prompt_at(slot, k, v, 0, plen)

    def write_prompt_at(self, slot, k: jax.Array, v: jax.Array, start,
                        plen) -> "PagedKVCache":
        """Suffix prefill (shared-prefix admission): write a fresh
        (1, Sp, KVH, D) sequence into ``slot``'s pages at logical
        positions [start, start + Sp) and set the slot's length to
        ``plen`` (the TOTAL sequence length — cached prefix + true
        suffix).  ``start`` may be a traced scalar; right-pad positions
        that run past the slot buffer are redirected to the trash page
        so a static pad width never corrupts allocated pages."""
        Sp = k.shape[1]
        t = jnp.asarray(start, jnp.int32) + jnp.arange(Sp, dtype=jnp.int32)
        page = jnp.clip(t // self.page_size, 0,
                        self.page_table.shape[1] - 1)
        phys = self.page_table[slot, page]                      # (Sp,)
        phys = jnp.where(t < self.buf, phys, TRASH_PAGE)
        off = t % self.page_size
        kcod, ksc = _kv_quant_any(k[0], self.fmt, self.block)
        vcod, vsc = _kv_quant_any(v[0], self.fmt, self.block)
        return PagedKVCache(
            self.k_codes.at[phys, off].set(kcod),
            self.k_scales.at[phys, off].set(ksc),
            self.v_codes.at[phys, off].set(vcod),
            self.v_scales.at[phys, off].set(vsc),
            self.page_table,
            self.lengths.at[slot].set(jnp.asarray(plen, jnp.int32)),
            self.fmt, self.block, self.page_size)

    def write_token(self, k: jax.Array, v: jax.Array,
                    mask: Optional[jax.Array] = None) -> "PagedKVCache":
        """Batched decode write: one (B, 1, KVH, D) token per slot at each
        slot's own length.  Inactive slots (freed mid-tick) write into the
        trash page their table rows point at — different live slots hold
        disjoint pages, so the scatter is collision-free where it matters.

        ``mask`` ((B,) bool, optional): slots with mask False are NOT
        decoding this step — their write is redirected to the trash page
        and their length does not advance.  This is how chunked prefill
        coexists with the static batched decode program: a mid-prefill
        slot's row points at REAL pages and its length is mid-prompt, so
        an unmasked decode write would scribble on prompt pages (and a
        length bump near the buffer edge could wrap ``lengths % buf``
        back onto page 0 of the slot).  Masked slots touch nothing."""
        posl = self.lengths % self.buf           # rolling == linear < buf
        page = posl // self.page_size
        off = posl % self.page_size
        phys = jnp.take_along_axis(self.page_table, page[:, None], 1)[:, 0]
        if mask is None:
            step = jnp.int32(1)
        else:
            m = jnp.asarray(mask, bool)
            phys = jnp.where(m, phys, TRASH_PAGE)
            step = m.astype(jnp.int32)
        kcod, ksc = _kv_quant_any(k[:, 0], self.fmt, self.block)
        vcod, vsc = _kv_quant_any(v[:, 0], self.fmt, self.block)
        return PagedKVCache(
            self.k_codes.at[phys, off].set(kcod),
            self.k_scales.at[phys, off].set(ksc),
            self.v_codes.at[phys, off].set(vcod),
            self.v_scales.at[phys, off].set(vsc),
            self.page_table, self.lengths + step,
            self.fmt, self.block, self.page_size)

    def write_tokens(self, k: jax.Array, v: jax.Array,
                     mask: Optional[jax.Array] = None) -> "PagedKVCache":
        """Batched multi-token write: S tokens per slot at each slot's own
        logical positions [len, len + S) — the teacher-forced verify block
        of speculative decoding.  Linear addressing only (the speculative
        path rejects SWA upstream: a rolling write could not be rolled
        back exactly).  Reuses the TRASH-page machinery of ``write_token``
        twice over: positions past the slot buffer AND every row of
        masked-off slots are redirected to the trash page, and masked
        slots' lengths do not advance.  Rejected rows are later undone by
        ``truncate_to`` — the pool keeps the stale codes but ``lengths``
        masks them out of every read."""
        B, S = k.shape[0], k.shape[1]
        t = (self.lengths[:, None]
             + jnp.arange(S, dtype=jnp.int32)[None, :])       # (B, S)
        page = jnp.clip(t // self.page_size, 0,
                        self.page_table.shape[1] - 1)
        phys = jnp.take_along_axis(self.page_table, page, 1)  # (B, S)
        phys = jnp.where(t < self.buf, phys, TRASH_PAGE)
        if mask is None:
            step = jnp.int32(S)
        else:
            m = jnp.asarray(mask, bool)
            phys = jnp.where(m[:, None], phys, TRASH_PAGE)
            step = m.astype(jnp.int32) * S
        off = t % self.page_size
        kcod, ksc = _kv_quant_any(k, self.fmt, self.block)
        vcod, vsc = _kv_quant_any(v, self.fmt, self.block)
        return PagedKVCache(
            self.k_codes.at[phys, off].set(kcod),
            self.k_scales.at[phys, off].set(ksc),
            self.v_codes.at[phys, off].set(vcod),
            self.v_scales.at[phys, off].set(vsc),
            self.page_table, self.lengths + step,
            self.fmt, self.block, self.page_size)

    def truncate_to(self, slot, new_len) -> "PagedKVCache":
        """Exact rollback of rejected appends: shrink length(s) to
        ``new_len`` without touching pool contents.  Rows in
        [new_len, old_len) become invisible immediately — every read
        masks by ``lengths`` (kv_len), the same mechanism that hides
        right-pad garbage — and the next append overwrites them in
        place, so no zeroing pass exists to diverge bit-wise.  Page
        refcounts live host-side (the scheduler's PagePool) and are
        untouched: pages stay with the slot, only the logical
        high-water mark moves.

        ``slot=None``: batched rollback, ``new_len`` a (B,) vector
        (broadcasts over a scan-stacked (L, B) ``lengths``).  Clamped so
        truncation can never extend a slot."""
        nl = jnp.asarray(new_len, jnp.int32)
        if slot is None:
            lens = jnp.minimum(self.lengths, nl)
        else:
            cur = self.lengths[slot]
            lens = self.lengths.at[slot].set(jnp.minimum(cur, nl))
        return dataclasses.replace(self, lengths=lens)

    # ---- reads ----------------------------------------------------------

    def gather_slots(self):
        """Gather the logical (B, buf, KVH, ·) packed views through the
        page table (the jnp mirror of the Pallas kernel's per-page DMA)."""
        pt = self.page_table

        def g(pool):
            a = pool[pt]                  # (B, n_pages, page, KVH, ·)
            return a.reshape((pt.shape[0], -1) + pool.shape[2:])

        return (g(self.k_codes), g(self.k_scales),
                g(self.v_codes), g(self.v_scales))

    def gather_slot(self, slot):
        """ONE slot's logical (1, buf, KVH, ·) packed views (``slot`` may
        be traced) — the read side of suffix prefill, which attends a
        single slot's pages while other slots keep decoding."""
        row = jax.lax.dynamic_index_in_dim(
            self.page_table, jnp.asarray(slot, jnp.int32), 0,
            keepdims=False)               # (n_pages,)

        def g(pool):
            a = pool[row]                 # (n_pages, page, KVH, ·)
            return a.reshape((1, -1) + pool.shape[2:])

        return (g(self.k_codes), g(self.k_scales),
                g(self.v_codes), g(self.v_scales))

    def dequant(self, dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
        """Full logical (B, buf, KVH, D) reconstruction — test oracle."""
        kc, ks, vc, vs = self.gather_slots()
        return (_kv_dequant_any(kc, ks, self.fmt, self.block, dtype),
                _kv_dequant_any(vc, vs, self.fmt, self.block, dtype))

    def nbytes(self) -> int:
        """Stored pool bytes (codes + scales, k and v)."""
        return int(sum(a.size * a.dtype.itemsize for a in
                       (self.k_codes, self.k_scales,
                        self.v_codes, self.v_scales)))


jax.tree_util.register_dataclass(
    PagedKVCache,
    data_fields=["k_codes", "k_scales", "v_codes", "v_scales",
                 "page_table", "lengths"],
    meta_fields=["fmt", "block", "page_size"])


def swa_kpos(lengths: jax.Array, buf: int) -> jax.Array:
    """Absolute position held by each logical slot of a rolling buffer:
    slot j holds the most recent token with pos % buf == j.  ``lengths``:
    (B,) per-slot lengths -> (B, buf); unwritten slots come out negative
    (mask with ``kpos >= 0`` or the kv_len rule)."""
    last = lengths[:, None] - 1
    slot = jnp.arange(buf, dtype=jnp.int32)[None, :]
    return last - ((last % buf - slot) % buf)


def make_kv_cache(batch: int, buf: int, n_kv: int, hd: int,
                  dtype=jnp.bfloat16, kv_format: str = "bf16",
                  page_size: Optional[int] = None,
                  total_pages: Optional[int] = None):
    """Cache-shape API: bf16 ``KVCache``, block-quantized ``PackedKVCache``,
    or (``page_size`` set) a ``PagedKVCache`` over a shared page pool."""
    if page_size:
        return PagedKVCache.init(batch, buf, n_kv, hd, fmt=kv_format,
                                 page_size=page_size,
                                 total_pages=total_pages)
    if kv_format == "bf16":
        return KVCache.init(batch, buf, n_kv, hd, dtype)
    return PackedKVCache.init(batch, buf, n_kv, hd, fmt=kv_format)


def _attn_decode_fused(q, k_codes, k_scales, v_codes, v_scales, fmt: str,
                       block: int, *, qpos, kpos, causal, window, kv_len,
                       chunk: int = 1024) -> jax.Array:
    """Fused decode attention core: flash-style scan over kv chunks with
    running (max, denom, acc) stats, dequantizing each chunk's K/V blocks
    inside the scan body — only one chunk of bf16 K/V ever exists at a
    time (the jnp mirror of the Pallas kernel's in-VMEM dequant).

    Positions may be SHARED or PER-SLOT (continuous batching):
      * qpos: (Sq,) or (B, Sq) absolute query positions;
      * kpos: (S_buf,) or (B, S_buf) absolute position held by each slot;
      * kv_len: None, scalar, or (B,) valid-slot counts.
    q: (B, Sq, H, D) with Sq small (decode: 1); codes/scales: the packed
    (B, S_buf, KVH, ·) layouts (``fmt`` "nvfp4"/"fp8"/"bf16").
    """
    B, Sq, H, D = q.shape
    KVH = k_codes.shape[2]
    G = H // KVH
    buf = k_codes.shape[1]
    kc = chunk if buf % chunk == 0 else buf
    nk = buf // kc
    scale = D ** -0.5
    qf = q.reshape(B, Sq, KVH, G, D).astype(jnp.float32)
    qpos = jnp.broadcast_to(jnp.atleast_2d(qpos), (B, Sq))
    kpos = jnp.broadcast_to(jnp.atleast_2d(kpos), (B, buf))
    if kv_len is not None:
        kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (B,))
        kpos = jnp.where(jnp.arange(buf)[None, :] < kv_len[:, None], kpos,
                         jnp.int32(2 ** 30))

    def chunked(a):
        return a.reshape((B, nk, kc) + a.shape[2:]).swapaxes(0, 1)

    kin = (chunked(k_codes), chunked(k_scales),
           chunked(v_codes), chunked(v_scales),
           kpos.reshape(B, nk, kc).swapaxes(0, 1))

    def kv_step(carry, xs):
        m, l, acc = carry                                  # (B,KVH,G,Sq[,D])
        kc_, ks_, vc_, vs_, kp = xs
        ki = _kv_dequant_any(kc_, ks_, fmt, block, jnp.float32)
        vi = _kv_dequant_any(vc_, vs_, fmt, block, jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, ki) * scale
        mask = jnp.ones((B, Sq, kc), bool)
        if causal:
            mask &= kp[:, None, :] <= qpos[:, :, None]
        if window is not None:
            mask &= kp[:, None, :] > qpos[:, :, None] - window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vi)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KVH, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), kin)
    o = acc / jnp.maximum(l, 1e-30)[..., None]             # (B,KVH,G,Sq,D)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D).astype(q.dtype)


def _attn_decode_packed(q, cache: PackedKVCache, *, qpos, kpos, causal,
                        window, kv_len, chunk: int = 1024) -> jax.Array:
    """Decode attention over a (non-paged) packed cache — see
    ``_attn_decode_fused`` for the scan; positions are shared scalars here."""
    return _attn_decode_fused(q, cache.k_codes, cache.k_scales,
                              cache.v_codes, cache.v_scales, cache.fmt,
                              cache.block, qpos=qpos, kpos=kpos,
                              causal=causal, window=window, kv_len=kv_len,
                              chunk=chunk)


def _attn_decode_paged(q, cache: PagedKVCache, *, qpos, kpos, causal,
                       window, kv_len, chunk: int = 1024) -> jax.Array:
    """Decode attention over a PAGED cache with per-slot lengths: gather
    the packed K/V tiles through the page table (still at packed width —
    the bf16 cache never exists), then run the fused per-slot scan."""
    kc, ks, vc, vs = cache.gather_slots()
    return _attn_decode_fused(q, kc, ks, vc, vs, cache.fmt, cache.block,
                              qpos=qpos, kpos=kpos, causal=causal,
                              window=window, kv_len=kv_len, chunk=chunk)


def attn_apply(p, x, ctx: QCtx, *, n_heads: int, n_kv: int, hd: int,
               rope_theta: float, causal: bool = True,
               window: Optional[int] = None, chunk: int = 1024,
               positions: Optional[jax.Array] = None,
               cache=None, slot=None, plen=None, pfx=None,
               write_mask: Optional[jax.Array] = None,
               xkv: Optional[jax.Array] = None,
               norm_eps: float = 1e-5, use_rope: bool = True):
    """Self- (or cross-, via xkv) attention with optional KV cache update.

    Returns (out, new_cache).  With a cache (``KVCache`` or block-quantized
    ``PackedKVCache``), x is the *new* tokens (decode: S=1; prefill:
    S=prompt) written at positions [cache.length, cache.length + S).  For
    SWA the cache buffer is min(window, S_buf) and written modulo buffer
    size (rolling).  Packed caches quantize writes (RtN along the head dim)
    and the decode read dequantizes blocks on the fly.

    With a ``PagedKVCache`` each batch row is an independent SLOT with its
    own length: decode (S=1, ``slot=None``) writes every slot's token at
    that slot's position and attends with per-slot kv_len/q_offset;
    prefill-into-slot (``slot`` given, B=1) writes a fresh right-padded
    prompt into one slot's pages and resets its length to ``plen``.
    With ``pfx`` (shared-prefix admission) x is only the SUFFIX of the
    prompt: its K/V rows are written at [pfx, pfx + S) and the queries
    attend THROUGH the paged cache — the shared prefix pages plus the
    just-written suffix rows, dequantized on the fly — so one compiled
    suffix program serves every (pfx, plen) warm admission.
    ``write_mask`` ((B,) bool, batched paged decode only): slots mid-
    chunked-prefill write to the trash page and keep their length (see
    ``PagedKVCache.write_token``).
    """
    B, S, d = x.shape
    src = x if xkv is None else xkv
    q = ctx.dense(x, p["wq"], p.get("bq"))
    k = ctx.dense(src, p["wk"], p.get("bk"))
    v = ctx.dense(src, p["wv"], p.get("bv"))
    q = constrain(q.reshape(B, S, n_heads, hd), "heads")
    k = k.reshape(B, src.shape[1], n_kv, hd)
    v = v.reshape(B, src.shape[1], n_kv, hd)

    paged = isinstance(cache, PagedKVCache)
    if positions is None:
        if paged:
            # per-slot positions (continuous batching); a fresh prefill
            # slot starts at 0, a suffix prefill at the cached prefix
            if slot is not None:
                base = 0 if pfx is None else jnp.asarray(pfx, jnp.int32)
                positions = base + jnp.arange(S, dtype=jnp.int32)
            else:
                positions = (cache.lengths[:, None]
                             + jnp.arange(S, dtype=jnp.int32)[None, :])
        else:
            base = cache.length if cache is not None else 0
            positions = base + jnp.arange(S, dtype=jnp.int32)

    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"], norm_eps)
        k = rmsnorm(k, p["k_norm"], norm_eps)

    if use_rope and xkv is None:
        cos_q, sin_q = rope_tables(positions, hd, rope_theta)
        if positions.ndim == 1:                # shared -> add batch dim;
            cos_q, sin_q = cos_q[None], sin_q[None]   # per-slot is (B, S, ·)
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_q, sin_q)

    new_cache = None
    if paged and xkv is None:
        buf = cache.buf
        if slot is not None and pfx is not None:
            # SUFFIX prefill (shared-prefix admission, B == 1): the slot's
            # prefix pages already hold [0, pfx); write the fresh suffix
            # rows at [pfx, pfx + S) and attend THROUGH the paged cache —
            # shared prefix + just-written suffix, dequantized on the fly
            # (right-pad rows land masked or on the trash page).
            if window is not None:
                raise NotImplementedError(
                    "shared-prefix suffix prefill needs a linear cache; "
                    "SWA rolling buffers rewrite shared pages")
            total = S if plen is None else plen
            new_cache = cache.write_prompt_at(slot, k, v, pfx, total)
            kc, ks, vc, vs = new_cache.gather_slot(slot)
            o = _attn_decode_fused(
                q, kc, ks, vc, vs, new_cache.fmt, new_cache.block,
                qpos=positions, kpos=jnp.arange(buf, dtype=jnp.int32),
                causal=causal, window=None,
                kv_len=jnp.asarray(total, jnp.int32), chunk=chunk)
        elif slot is not None:
            # prefill-into-slot (B == 1): write the fresh sequence into the
            # slot's pages; attend within the fresh tokens directly (right-
            # pad rows are garbage queries whose outputs the caller drops).
            new_cache = cache.write_prompt(
                slot, k, v, S if plen is None else plen)
            o = attention_core(q, k, v, qpos=positions, kpos=positions,
                               causal=causal, window=window, chunk=chunk)
        elif S == 1:
            # batched decode: per-slot write + per-slot read
            new_cache = cache.write_token(k, v, mask=write_mask)
            lengths = new_cache.lengths                   # post-write
            if window is not None:
                kpos = swa_kpos(lengths, buf)
            else:
                kpos = jnp.broadcast_to(
                    jnp.arange(buf, dtype=jnp.int32)[None, :], (B, buf))
            kv_len = jnp.minimum(lengths, buf)
            o = _attn_decode_paged(q, new_cache, qpos=positions, kpos=kpos,
                                   causal=causal, window=window,
                                   kv_len=kv_len, chunk=chunk)
        else:
            # batched verify (speculative decode, S == k): write the k
            # teacher-forced rows at each slot's [len, len + k), then read
            # through the page table with per-slot positions.  Causal
            # masking makes query row j see exactly rows [0, len + j] —
            # the same set the sequential decode of token j would see —
            # and RtN row quantization is neighbor-independent, so each
            # row's logits are BIT-identical to non-speculative decode.
            if window is not None:
                raise NotImplementedError(
                    "speculative verify needs a linear paged cache; SWA "
                    "rolling buffers cannot roll back exactly")
            new_cache = cache.write_tokens(k, v, mask=write_mask)
            kpos = jnp.broadcast_to(
                jnp.arange(buf, dtype=jnp.int32)[None, :], (B, buf))
            kv_len = jnp.minimum(new_cache.lengths, buf)
            o = _attn_decode_paged(q, new_cache, qpos=positions, kpos=kpos,
                                   causal=causal, window=None,
                                   kv_len=kv_len, chunk=chunk)
    elif cache is not None and xkv is None:
        packed = isinstance(cache, PackedKVCache)
        buf = (cache.k_codes if packed else cache.k).shape[1]
        start = cache.length % buf if window is not None else cache.length
        # rolling write (SWA) or linear write; S tokens, may wrap for SWA.
        # If more new tokens than buffer slots, only the last `buf` survive —
        # slice first so `.at[idx].set` never sees duplicate indices.
        kw, vw, Sw = k, v, S
        if S > buf:
            kw, vw, Sw = k[:, S - buf:], v[:, S - buf:], buf
            start = (cache.length + (S - buf)) % buf
        idx = (start + jnp.arange(Sw, dtype=jnp.int32)) % buf
        new_len = cache.length + S
        if packed:
            from repro.core.quantize import kv_quant_rows
            kcod, ksc = kv_quant_rows(kw, cache.fmt, cache.block)
            vcod, vsc = kv_quant_rows(vw, cache.fmt, cache.block)
            new_cache = PackedKVCache(
                cache.k_codes.at[:, idx].set(kcod),
                cache.k_scales.at[:, idx].set(ksc),
                cache.v_codes.at[:, idx].set(vcod),
                cache.v_scales.at[:, idx].set(vsc),
                new_len, cache.fmt, cache.block)
        else:
            ck = cache.k.at[:, idx].set(kw)
            cv = cache.v.at[:, idx].set(vw)
            new_cache = KVCache(ck, cv, new_len)
        if S > 1:
            # Prefill (assumed from an empty cache): attend within the fresh
            # sequence directly — correct for SWA even when S > buf, since
            # every query's window lies inside the fresh K/V.
            o = attention_core(q, k, v, qpos=positions, kpos=positions,
                               causal=causal, window=window, chunk=chunk)
        else:
            # Decode: attend the cache buffer.  Absolute position held by
            # each slot: for SWA, slot j holds the most recent token with
            # pos % buf == j; linear caches store pos == slot.
            if window is not None:
                slot = jnp.arange(buf, dtype=jnp.int32)
                last = new_len - 1
                kpos = last - ((last % buf - slot) % buf)
            else:
                kpos = jnp.arange(buf, dtype=jnp.int32)
            kv_len = jnp.minimum(new_len, buf)
            if packed:
                # dequantize-fused read: K/V blocks decode inside the score
                # loop instead of materializing a bf16 cache first
                o = _attn_decode_packed(q, new_cache, qpos=positions,
                                        kpos=kpos, causal=causal,
                                        window=window, kv_len=kv_len,
                                        chunk=chunk)
            else:
                o = attention_core(q, ck, cv, qpos=positions, kpos=kpos,
                                   causal=causal, window=window, chunk=chunk,
                                   kv_len=kv_len)
    else:
        kpos = (positions if xkv is None
                else jnp.arange(src.shape[1], dtype=jnp.int32))
        o = attention_core(q, k, v, qpos=positions, kpos=kpos,
                           causal=causal and xkv is None, window=window,
                           chunk=chunk)

    o = constrain(o, "heads")
    out = ctx.dense(o.reshape(B, S, n_heads * hd), p["wo"])
    return out, new_cache


# ---- MLP block -------------------------------------------------------------------


def mlp_params(key, d_model: int, d_ff: int, act: str, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    if act in ("swiglu", "smooth_swiglu"):
        p = {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
        if act == "smooth_swiglu":
            p["smooth"] = jnp.ones((d_ff,), dtype)
        return p
    return {
        "w_in": dense_init(ks[0], d_model, d_ff, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(ks[1], d_ff, d_model, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def mlp_apply(p, x, ctx: QCtx, act: str):
    if act in ("swiglu", "smooth_swiglu"):
        g = constrain(ctx.dense(x, p["w_gate"]), "hidden")
        u = constrain(ctx.dense(x, p["w_up"]), "hidden")
        if act == "smooth_swiglu":
            h = smooth_swiglu(g, u, p["smooth"])
            return ctx.dense(h, p["w_down"]) * 1.0  # scale folded into w_down
        h = swiglu(g, u)
        return ctx.dense(h, p["w_down"])
    h = jax.nn.gelu(ctx.dense(x, p["w_in"], p["b_in"]).astype(jnp.float32))
    h = constrain(h, "hidden")
    return ctx.dense(h.astype(x.dtype), p["w_out"], p["b_out"])
