"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort-based
dispatch (megablocks-style gather/scatter — no giant one-hot dispatch
einsums), experts computed by a lax.scan over stacked expert weights so HLO
size is O(1) in the expert count (qwen3: 128 experts).

**Group-limited dispatch** (GShard-style): tokens are split into
``cfg.moe_groups`` groups, each routed independently with capacity C/G.
With groups pinned to the data-parallel axis, the argsort/scatter of the
dispatch runs *locally per shard* instead of sorting the global token
array — this removed the all-gather storm that made the qwen3 prefill cell
collective-bound at baseline (EXPERIMENTS.md §Perf).  ``moe_groups = 0``
(smoke-test default) keeps one global group.

The router runs in bf16 (precision-critical, tiny — DESIGN.md §5); expert
FFN GEMMs go through the FQT path like every other linear.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import QCtx, dense_init, swiglu, smooth_swiglu


def moe_params(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff

    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dtype))(
            jax.random.split(k, E))

    p = {
        "router": dense_init(ks[0], d, E, dtype, scale=0.02),
        "w_gate": stack(ks[1], d, f),
        "w_up": stack(ks[2], d, f),
        "w_down": stack(ks[3], f, d),
    }
    if cfg.act == "smooth_swiglu":
        p["smooth"] = jnp.ones((E, f), dtype)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(cfg.top_k, (c + 3) // 4 * 4)


def _dispatch(x, logits, cfg: ModelConfig, C: int):
    """Per-group routing.  x: (Tg, d), logits: (Tg, E).

    Returns (xe (E, C, d), combine metadata, aux loss)."""
    Tg, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)               # (Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)         # renorm

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1),
        axis=0)
    aux = E * jnp.sum(me * ce)

    # sort-based dispatch (local to the group)
    flat_e = expert_idx.reshape(-1)                                # (Tg*K,)
    flat_t = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), K)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank within expert = position - first position of that expert
    first = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype), side="left")
    rank = jnp.arange(Tg * K, dtype=jnp.int32) - first[se]
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)                   # dustbin

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(x[st])
    xe = buf[: E * C].reshape(E, C, d)
    return xe, (slot, st, sg, keep), aux


def _combine(ye, meta, Tg: int):
    """ye: (E, C, d) -> y: (Tg, d) using the dispatch metadata."""
    slot, st, sg, keep = meta
    E_C, d = ye.shape[0] * ye.shape[1], ye.shape[2]
    ye_flat = jnp.concatenate(
        [ye.reshape(E_C, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    contrib = ye_flat[slot] * sg[:, None].astype(ye.dtype)
    return jnp.zeros((Tg, d), ye_flat.dtype).at[st].add(
        jnp.where(keep[:, None], contrib, 0))


def moe_apply(p, x: jax.Array, ctx: QCtx,
              cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (T, d) -> (y: (T, d), aux_loss scalar)."""
    T, d = x.shape
    E = cfg.n_experts
    G = cfg.moe_groups if (cfg.moe_groups and T % cfg.moe_groups == 0) else 1
    Tg = T // G
    C = _capacity(Tg, cfg)

    # ---- routing (bf16, full precision router) ----
    logits = ctx.dense_hp(x, p["router"]).astype(jnp.float32)      # (T, E)

    xg = constrain(x.reshape(G, Tg, d), "groups")           # groups -> dp
    lg = logits.reshape(G, Tg, E)
    xe, meta, aux = jax.vmap(
        lambda xi, li: _dispatch(xi, li, cfg, C))(xg, lg)
    aux = jnp.mean(aux)
    # (G, E, C, d) -> (E, G*C, d): per-expert GEMMs batched over groups
    xe = constrain(xe, "groups")
    xe = xe.swapaxes(0, 1).reshape(E, G * C, d)

    # ---- expert FFN (scan over experts; FQT dense) ----
    smooth = p.get("smooth")

    def one_expert(carry, inp):
        if smooth is not None:
            wg, wu, wd, sm, eidx = inp
        else:
            (wg, wu, wd, eidx), sm = inp, None
        ectx = ctx.fold(eidx)
        xi = xe[eidx]
        g = ectx.dense(xi, wg)
        u = ectx.dense(xi, wu)
        h = smooth_swiglu(g, u, sm) if sm is not None else swiglu(g, u)
        return carry, ectx.dense(h, wd)

    eidx = jnp.arange(E, dtype=jnp.int32)
    xs = ((p["w_gate"], p["w_up"], p["w_down"], p["smooth"], eidx)
          if smooth is not None
          else (p["w_gate"], p["w_up"], p["w_down"], eidx))
    _, ye = jax.lax.scan(one_expert, None, xs)               # (E, G*C, d)

    # ---- combine (per group) ----
    ye = constrain(ye.reshape(E, G, C, d).swapaxes(0, 1),
                   "groups")                                  # (G, E, C, d)
    y = jax.vmap(lambda yi, mi: _combine(yi, mi, Tg))(ye, meta)
    return y.reshape(T, d).astype(x.dtype), aux
