"""Model architecture configuration shared by every family in the zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None
    qkv_bias: bool = False
    sliding_window: Optional[int] = None      # SWA (mixtral)
    rope_theta: float = 10000.0
    act: str = "smooth_swiglu"                # smooth_swiglu | swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    quantize_lm_head: bool = True             # paper: *all* GEMMs in FP4
    use_qk_norm: bool = False                 # qwen3-style q/k RMSNorm

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # group-limited (GShard-style) dispatch: tokens routed in G independent
    # groups pinned to the DP axis -> dispatch sort/scatter is shard-local.
    # 0 = one global group (smoke default); production configs set 16.
    moe_groups: int = 0

    # hybrid (zamba2): mamba2 backbone + one *shared* attention block applied
    # every `attn_every` layers; ssm params
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_every: int = 0
    n_ssm_heads: int = 0

    # xLSTM: every `slstm_every`-th block is sLSTM, the rest mLSTM
    slstm_every: int = 0
    proj_factor: float = 2.0

    # enc-dec (whisper): encoder depth; frontend supplies frame embeddings
    enc_layers: int = 0
    enc_seq: int = 1500

    # vlm (internvl2): stub patch-embedding prefix length
    vision_tokens: int = 0

    # attention chunking (flash-style) kicks in above this seq len
    attn_chunk: int = 1024

    # padded vocab for TP divisibility (set in __post_init__ consumers)
    vocab_pad_multiple: int = 256

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run 500k-token decode? (DESIGN.md §5 skip rule)"""
        return (self.family in ("hybrid", "ssm")
                or self.sliding_window is not None)

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers,
                         4 if (self.attn_every or self.slstm_every) else 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 16),
            n_ssm_heads=min(self.n_ssm_heads, 4) if self.n_ssm_heads else 0,
            attn_every=2 if self.attn_every else 0,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=32 if self.enc_layers else 0,
            vision_tokens=16 if self.vision_tokens else 0,
            sliding_window=64 if self.sliding_window else None,
            attn_chunk=64,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
