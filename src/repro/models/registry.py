"""Uniform model API over all families.

  init_params(cfg, key)                     -> params
  loss_fn(params, cfg, qcfg, batch, seed)   -> (loss, metrics)
  forward(params, cfg, qcfg, tokens, ...)   -> (logits, aux)
  make_decode_state(cfg, batch, max_len)    -> carry for decode_step
  decode_step(params, cfg, qcfg, tok, c)    -> (logits, carry)

The VLM family reuses the dense transformer with a prefix of precomputed
patch embeddings (frontend stub per the assignment).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.fqt import QuantConfig
from repro.models import mamba2, transformer, whisper, xlstm
from repro.models.config import ModelConfig

_TRANSFORMER_FAMILIES = ("dense", "moe", "vlm")


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.init(cfg, key, dtype)
    if cfg.family == "hybrid":
        return mamba2.init(cfg, key, dtype)
    if cfg.family == "ssm":
        return xlstm.init(cfg, key, dtype)
    if cfg.family == "encdec":
        return whisper.init(cfg, key, dtype)
    raise ValueError(f"unknown family {cfg.family!r}")


def loss_fn(params, cfg: ModelConfig, qcfg: QuantConfig,
            batch: Dict[str, Any], *, seed=0, remat: bool = True):
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.loss_fn(params, cfg, qcfg, batch, seed=seed,
                                   remat=remat)
    if cfg.family == "hybrid":
        return mamba2.loss_fn(params, cfg, qcfg, batch, seed=seed,
                              remat=remat)
    if cfg.family == "ssm":
        return xlstm.loss_fn(params, cfg, qcfg, batch, seed=seed, remat=remat)
    if cfg.family == "encdec":
        return whisper.loss_fn(params, cfg, qcfg, batch, seed=seed,
                               remat=remat)
    raise ValueError(cfg.family)


def forward(params, cfg: ModelConfig, qcfg: QuantConfig, batch, *, seed=0,
            remat: bool = False):
    tokens = batch["tokens"]
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.forward(params, cfg, qcfg, tokens, seed=seed,
                                   prefix_embeds=batch.get("prefix_embeds"),
                                   remat=remat)
    if cfg.family == "hybrid":
        return mamba2.forward(params, cfg, qcfg, tokens, seed=seed,
                              remat=remat)
    if cfg.family == "ssm":
        return xlstm.forward(params, cfg, qcfg, tokens, seed=seed,
                             remat=remat)
    if cfg.family == "encdec":
        return whisper.forward(params, cfg, qcfg, tokens,
                               frames=batch.get("frames"), seed=seed,
                               remat=remat)
    raise ValueError(cfg.family)


def make_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, kv_cache_format: str = "bf16",
                      page_size=None, total_pages=None):
    """Carry passed to decode_step; represents a cache filled to max_len
    capacity (dry-run shapes: the decode cell is 'one new token against a
    seq_len-deep cache').

    ``kv_cache_format``: "bf16" (default), "nvfp4" or "fp8" — attention KV
    caches are stored block-quantized along the head dim (PackedKVCache)
    and dequantized on the fly by the decode read.  The ssm family has no
    KV cache; its O(1) recurrent state always stays in high precision.

    ``page_size``: when set, attention KV caches become ``PagedKVCache``s
    over a shared page pool with PER-SLOT lengths — the storage behind
    continuous batching (serve/scheduler.py).  ``total_pages`` sizes the
    pool (default: one full reservation per slot plus the trash page).
    """
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.init_cache(cfg, batch, max_len, dtype,
                                      kv_cache_format, page_size,
                                      total_pages)
    if cfg.family == "hybrid":
        return (mamba2.init_state(cfg, batch, dtype),
                mamba2.init_cache(cfg, batch, max_len, dtype,
                                  kv_cache_format, page_size, total_pages))
    if cfg.family == "ssm":
        return xlstm.init_state(cfg, batch)
    if cfg.family == "encdec":
        enc_out = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dtype)
        return (enc_out, whisper.init_cache(cfg, batch, max_len, dtype,
                                            kv_cache_format, page_size,
                                            total_pages))
    raise ValueError(cfg.family)


def prefill(params, cfg: ModelConfig, qcfg: QuantConfig, tokens, carry,
            *, seed=0, extras=None):
    """Fill the decode carry from a prompt.  Returns (last_logits, carry).

    Transformer/enc-dec families use the native batched prefill; the
    recurrent families (hybrid/ssm) prefill by scanning decode_step over the
    prompt (reference implementation — their decode state is O(1) so this
    is memory-optimal, just not chunk-parallel).
    """
    extras = extras or {}
    if cfg.family in _TRANSFORMER_FAMILIES:
        logits, caches = transformer.prefill(
            params, cfg, qcfg, tokens, carry, seed=seed,
            prefix_embeds=extras.get("prefix_embeds"))
        return logits[:, -1], caches
    if cfg.family == "encdec":
        frames = extras.get("frames")
        if frames is None:
            frames = jnp.zeros((tokens.shape[0], cfg.enc_seq, cfg.d_model),
                               jnp.bfloat16)
        enc_out = whisper.encode(params, cfg, qcfg, frames, seed=seed)
        logits, carry = whisper.prefill(params, cfg, qcfg, tokens, enc_out,
                                        carry[1], seed=seed)
        return logits[:, -1], carry

    def body(c, tok):
        logits, c = decode_step(params, cfg, qcfg, tok[:, None], c,
                                seed=seed)
        return c, logits[:, -1]

    carry, logits = jax.lax.scan(body, carry, tokens.T)
    return logits[-1], carry


def prefill_slot(params, cfg: ModelConfig, qcfg: QuantConfig, tokens,
                 carry, slot, plen, *, seed=0, extras=None):
    """Prefill ONE slot of a paged decode carry from a right-padded (1, Sp)
    prompt (continuous batching admission).  Returns (logits (1, V), carry).

    Supported for the attention-prefillable families (dense/moe
    transformers and the whisper decoder).  The recurrent families
    (hybrid/ssm) absorb every input token into O(1) state, so a static-
    shape right-padded prefill would pollute their state with pad tokens —
    they stay on the lockstep engine until a masked-scan prefill lands.
    """
    extras = extras or {}
    if cfg.family in ("dense", "moe"):
        return transformer.prefill_slot(params, cfg, qcfg, tokens, carry,
                                        slot, plen, seed=seed)
    if cfg.family == "encdec":
        enc_out, caches = carry
        frames = extras.get("frames")
        if frames is None:
            frames = jnp.zeros((1, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        enc_slot = whisper.encode(params, cfg, qcfg, frames, seed=seed)
        enc_out = jax.lax.dynamic_update_slice_in_dim(
            enc_out, enc_slot.astype(enc_out.dtype),
            jnp.asarray(slot, jnp.int32), axis=0)
        logits, caches = whisper.prefill_slot(params, cfg, qcfg, tokens,
                                              enc_slot, caches, slot, plen,
                                              seed=seed)
        return logits, (enc_out, caches)
    raise NotImplementedError(
        f"prefill_slot: family {cfg.family!r} not supported (recurrent "
        f"state cannot be prefilled from a right-padded static shape)")


def prefill_suffix(params, cfg: ModelConfig, qcfg: QuantConfig, tokens,
                   carry, slot, plen, pfx, *, seed=0):
    """Prefill ONE slot from a right-padded (1, Sp) prompt SUFFIX whose
    first ``pfx`` tokens are already cached in shared prefix pages (warm
    admission, serve/prefix_cache.py).  Returns (logits (1, V), carry).

    Dense/moe transformers only: their self-attention K/V depend causally
    on prompt tokens alone, so identical prefixes produce bit-identical
    quantized pages.  The whisper decoder's K/V mix in per-request encoder
    output (frames) and the recurrent families have no pageable cache —
    neither can share prefix pages across requests.
    """
    if cfg.family in ("dense", "moe"):
        return transformer.prefill_suffix(params, cfg, qcfg, tokens, carry,
                                          slot, plen, pfx, seed=seed)
    raise NotImplementedError(
        f"prefill_suffix: family {cfg.family!r} cannot share prefix pages "
        f"(K/V are not a pure function of the prompt prefix)")


def prefill_chunk(params, cfg: ModelConfig, qcfg: QuantConfig, tokens,
                  carry, slot, off, *, seed=0):
    """Write one FULL intermediate chunk (1, C) of a prompt into a paged
    slot at logical positions [off, off + C) — the chunked-prefill
    program (no logits, no sampling; the final chunk goes through
    ``prefill_suffix``).  Returns the updated carry.

    Dense/moe transformers only, same reasoning as ``prefill_suffix``:
    the chunk attends THROUGH the quantized paged cache, so its rows are
    a pure function of the prompt prefix and chunking is exact."""
    if cfg.family in ("dense", "moe"):
        return transformer.prefill_chunk(params, cfg, qcfg, tokens, carry,
                                         slot, off, seed=seed)
    raise NotImplementedError(
        f"prefill_chunk: family {cfg.family!r} cannot prefill through the "
        f"paged cache (K/V are not a pure function of the prompt prefix)")


def verify_k(params, cfg: ModelConfig, qcfg: QuantConfig, tokens, carry,
             *, seed=0, write_mask=None):
    """Teacher-forced speculative verify: ``tokens`` (B, k) written into
    the paged carry at each slot's [len, len + k) and attended with
    per-slot causal positions — row j's logits are bit-identical to
    sequential decode (see ``transformer.verify_k``).  Returns
    (logits (B, k, V), carry).

    Dense/moe transformers only: verification needs an exactly
    rewindable cache (``PagedKVCache.truncate_to``); recurrent state
    absorbs drafted tokens irreversibly, and the whisper decoder's
    cross-attention carry is out of scope for the paged engine."""
    if cfg.family in ("dense", "moe"):
        return transformer.verify_k(params, cfg, qcfg, tokens, carry,
                                    seed=seed, write_mask=write_mask)
    raise NotImplementedError(
        f"verify_k: family {cfg.family!r} cannot roll back rejected "
        f"drafts (no exactly-truncatable paged cache)")


def draft_view(params, carry, draft_layers: int):
    """Self-draft truncation of the SAME stacked weights/caches to the
    first ``draft_layers`` layers (zero extra HBM — a trace-level slice
    of the layer axis; see ``transformer.draft_view``).  Use with
    ``dataclasses.replace(cfg, n_layers=draft_layers)``."""
    return transformer.draft_view(params, carry, draft_layers)


def decode_step(params, cfg: ModelConfig, qcfg: QuantConfig, tokens, carry,
                *, seed=0, write_mask=None):
    """``write_mask`` ((B,) bool): paged dense/moe decode only — slots
    mid-chunked-prefill write to the trash page and keep their length."""
    if cfg.family in _TRANSFORMER_FAMILIES:
        return transformer.decode_step(params, cfg, qcfg, tokens, carry,
                                       seed=seed, write_mask=write_mask)
    if write_mask is not None:
        raise NotImplementedError(
            f"decode_step write_mask: family {cfg.family!r} has no paged "
            f"cache write to mask (chunked prefill is dense/moe only)")
    if cfg.family == "hybrid":
        return mamba2.decode_step(params, cfg, qcfg, tokens, carry, seed=seed)
    if cfg.family == "ssm":
        return xlstm.decode_step(params, cfg, qcfg, tokens, carry, seed=seed)
    if cfg.family == "encdec":
        return whisper.decode_step(params, cfg, qcfg, tokens, carry,
                                   seed=seed)
    raise ValueError(cfg.family)
