"""Mamba2 (SSD) blocks + the zamba2-style hybrid backbone.

The SSD state-space core is computed with the chunk-parallel algorithm
(intra-chunk quadratic attention-like term + inter-chunk recurrent state
passing via lax.scan), which is the TPU-friendly form: all heavy lifting is
MXU matmuls over (chunk x chunk) and (chunk x state) tiles.  The in/out/gate
projections — the GEMMs a Blackwell-class chip would run in FP4 — go through
the FQT path; the elementwise recurrence itself stays bf16/f32 (no GEMM to
accelerate; DESIGN.md §5).

zamba2 hybrid: a backbone of Mamba2 blocks with ONE shared attention block
(weights shared) applied every ``attn_every`` layers, each application with
its own LayerNorm (simplification of zamba2's concat-reinjection, noted in
DESIGN.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fqt import QuantConfig
from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import (QCtx, attn_apply, attn_params, dense_init,
                                 embed_init, make_kv_cache, mlp_params,
                                 mlp_apply, rmsnorm)

_SEED_STRIDE = jnp.uint32(0x9E3779B9)


# ---- Mamba2 block -------------------------------------------------------------


def mamba_dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = cfg.n_ssm_heads or max(1, d_inner // 64)
    P = d_inner // H                       # head dim
    N = cfg.ssm_state                      # state dim
    return d_inner, H, P, N


def mamba_params(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d_inner, H, P, N = mamba_dims(cfg)
    ks = jax.random.split(key, 5)
    # in_proj emits [z(d_inner), x(d_inner), B(N), C(N), dt(H)]
    d_in_proj = 2 * d_inner + 2 * N + H
    p = {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj, dtype),
        "out_proj": dense_init(ks[1], d_inner, cfg.d_model, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, d_inner + 2 * N),
                                     jnp.float32) * 0.2).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along time.  x: (B,S,C); w: (K,C).

    Returns (y, new_state) where state carries the last K-1 inputs."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int):
    """Chunk-parallel SSD.  xh:(B,S,H,P) dt:(B,S,H) A:(H,) Bm/Cm:(B,S,N).

    y[t] = C[t] . h[t],  h[t] = exp(dt[t]A) h[t-1] + dt[t] B[t] x[t]^T
    (per head; B/C shared across heads — multi-value attention form of SSD).
    Returns (y, final_state (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    nc = S // chunk
    xc = xh.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    # per-step log decay  a[t] = dt[t] * A  (A negative)
    la = dtc * A[None, None, None, :]                 # (B,nc,c,H) log-decay
    csum = jnp.cumsum(la, axis=2)                     # within-chunk cumsum

    # ---- intra-chunk (quadratic in chunk len, like masked attention) ----
    # L[s,t] = exp(csum[s] - csum[t]) for s >= t  (decay from t+1..s)
    diff = csum[:, :, :, None, :] - csum[:, :, None, :, :]   # (B,nc,c,c,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: above-diagonal diff is positive (csum decreasing) and
    # would overflow exp for long chunks
    Ldec = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -1e30))
    scores = jnp.einsum("bcsn,bctn->bcst", Cc, Bc)            # (B,nc,c,c)
    W = scores[..., None] * Ldec * dtc[:, :, None, :, :]      # (B,nc,s,t,H)
    y_intra = jnp.einsum("bcsth,bcthp->bcshp", W, xc)

    # ---- chunk states ----
    # state_c = sum_t exp(csum[last] - csum[t]) dt[t] B[t] x[t]^T
    decay_to_end = jnp.exp(csum[:, :, -1:, :] - csum)          # (B,nc,c,H)
    sbx = jnp.einsum("bcth,bctn,bcthp->bchpn",
                     decay_to_end * dtc, Bc, xc)               # (B,nc,H,P,N)

    # ---- inter-chunk scan ----
    chunk_decay = jnp.exp(csum[:, :, -1, :])                   # (B,nc,H)

    def scan_body(h, per_chunk):
        s_new, dec = per_chunk                                 # (B,H,P,N),(B,H)
        h_out = h                                              # state entering
        h = h * dec[:, :, None, None] + s_new
        return h, h_out

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    hT, h_in = jax.lax.scan(scan_body, h0,
                            (sbx.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)                                 # (B,nc,H,P,N)

    # contribution of the entering state to each position
    decay_from_start = jnp.exp(csum)                           # (B,nc,c,H)
    y_inter = jnp.einsum("bcsn,bchpn,bcsh->bcshp", Cc, h_in, decay_from_start)

    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, hT


def mamba_apply(p, x, ctx: QCtx, cfg: ModelConfig, *,
                state=None, chunk: int = 64):
    """One Mamba2 block.  state: None (train) or dict(conv, ssm) for decode.

    Returns (y, new_state)."""
    B, S, d = x.shape
    d_inner, H, P, N = mamba_dims(cfg)
    zxbcdt = ctx.dense(x, p["in_proj"])
    z, xr, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    conv_in = constrain(jnp.concatenate([xr, Bm, Cm], axis=-1), "hidden")
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    xr, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    xh = xr.reshape(B, S, H, P).astype(jnp.float32)
    Bm32, Cm32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    if state is None:
        c = min(chunk, S)
        if S % c:
            raise ValueError(f"seq {S} not divisible by ssm chunk {c}")
        y, hT = _ssd_chunked(xh, dt, A, Bm32, Cm32, c)
    else:
        # decode: S == 1 single recurrent step
        h = state["ssm"]                                          # (B,H,P,N)
        dec = jnp.exp(dt[:, 0] * A[None, :])                      # (B,H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Bm32[:, 0], xh[:, 0])
        h = h * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm32[:, 0], h)[:, None]    # (B,1,H,P)
        hT = h

    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)    # gate
    out = ctx.dense(y, p["out_proj"])
    new_state = {"conv": new_conv, "ssm": hT}
    return out, new_state


# ---- zamba2 hybrid backbone ----------------------------------------------------


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    kE, kM, kA, kH, kF = jax.random.split(key, 5)
    mamba_layers = jax.vmap(
        lambda k: mamba_params(k, cfg, dtype))(
        jax.random.split(kM, cfg.n_layers))
    params = {
        "embed": embed_init(kE, cfg.padded_vocab, cfg.d_model, dtype),
        "mamba": mamba_layers,
        "mamba_ln": jnp.ones((cfg.n_layers, cfg.d_model), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(kH, cfg.d_model, cfg.padded_vocab, dtype),
    }
    if cfg.attn_every:
        params["shared_attn"] = {
            "attn": attn_params(kA, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, dtype=dtype),
            "mlp": mlp_params(kF, cfg.d_model, cfg.d_ff, "swiglu", dtype),
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
        }
    return params


def _n_attn(cfg: ModelConfig) -> int:
    return (cfg.n_layers // cfg.attn_every) if cfg.attn_every else 0


def _apply_backbone(params, cfg, qcfg, x, seed, *, states, caches,
                    remat=False, ssm_chunk=64):
    """Mamba layers with the shared attention block interleaved.

    The mamba stack is scanned in groups of ``attn_every``; the (shared)
    attention block runs between groups with its own KV cache per
    application."""
    L, ae = cfg.n_layers, (cfg.attn_every or cfg.n_layers)
    n_groups = (L + ae - 1) // ae
    seeds = jnp.asarray(seed, jnp.uint32) + jnp.arange(
        L, dtype=jnp.uint32) * _SEED_STRIDE

    def mamba_body(x, per_layer):
        lp, ln_w, s, st = per_layer
        ctx = QCtx(qcfg, s)
        x = constrain(x, "res")
        y, new_st = mamba_apply(lp, rmsnorm(x, ln_w, cfg.norm_eps), ctx, cfg,
                                state=st, chunk=ssm_chunk)
        return x + y, new_st

    if remat:
        mamba_body = jax.checkpoint(
            mamba_body, policy=jax.checkpoint_policies.nothing_saveable)

    def slice_group(tree, g0, g1):
        return jax.tree.map(lambda a: a[g0:g1], tree)

    new_states, new_caches = [], []
    for g in range(n_groups):
        g0, g1 = g * ae, min((g + 1) * ae, L)
        xs = (slice_group(params["mamba"], g0, g1),
              params["mamba_ln"][g0:g1], seeds[g0:g1],
              slice_group(states, g0, g1) if states is not None else None)
        x, st = jax.lax.scan(mamba_body, x, xs)
        new_states.append(st)
        if cfg.attn_every and g1 % ae == 0 and "shared_attn" in params:
            sp = params["shared_attn"]
            ctx = QCtx(qcfg, jnp.asarray(seed, jnp.uint32)
                       + jnp.uint32(0x51ED2701 + g))
            cache_g = caches[g] if caches is not None else None
            h, nc = attn_apply(
                sp["attn"], rmsnorm(x, sp["ln1"], cfg.norm_eps), ctx,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
                rope_theta=cfg.rope_theta, chunk=cfg.attn_chunk,
                cache=cache_g)
            x = x + h
            x = x + mlp_apply(sp["mlp"], rmsnorm(x, sp["ln2"], cfg.norm_eps),
                              ctx, "swiglu")
            new_caches.append(nc)
    states_out = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_states)
    return x, states_out, new_caches


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    """Per-layer SSM + conv states (decode) and shared-attn KV caches."""
    d_inner, H, P, N = mamba_dims(cfg)

    def one(_):
        return {
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner + 2 * N),
                              dtype),
            "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        }

    states = jax.vmap(one)(jnp.arange(cfg.n_layers))
    return states


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, kv_format: str = "bf16",
               page_size=None, total_pages=None):
    buf = max_len
    if page_size:
        buf = -(-buf // page_size) * page_size
    return [make_kv_cache(batch, buf, cfg.n_kv_heads, cfg.hd, dtype,
                          kv_format, page_size=page_size,
                          total_pages=total_pages)
            for _ in range(_n_attn(cfg))]


def forward(params, cfg: ModelConfig, qcfg: QuantConfig, tokens, *, seed=0,
            remat: bool = True, ssm_chunk: int = 64):
    x = constrain(params["embed"][tokens], "res")
    x, _, _ = _apply_backbone(params, cfg, qcfg, x, seed, states=None,
                              caches=None, remat=remat, ssm_chunk=ssm_chunk)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    ctx = QCtx(qcfg if cfg.quantize_lm_head else QuantConfig(),
               jnp.asarray(seed, jnp.uint32) + jnp.uint32(0xABCDEF))
    logits = constrain(ctx.dense(x, params["lm_head"]), "logits")
    return logits, jnp.zeros((), jnp.float32)


def decode_step(params, cfg, qcfg, tokens, carry, *, seed=0):
    """carry = (states, caches).  tokens: (B,1)."""
    states, caches = carry
    x = params["embed"][tokens]
    x, new_states, new_caches = _apply_backbone(
        params, cfg, qcfg, x, seed, states=states, caches=caches,
        remat=False)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    ctx = QCtx(qcfg if cfg.quantize_lm_head else QuantConfig(),
               jnp.asarray(seed, jnp.uint32) + jnp.uint32(0xABCDEF))
    logits = ctx.dense(x, params["lm_head"])
    return logits, (new_states, new_caches)


def loss_fn(params, cfg, qcfg, batch, *, seed=0, remat=True,
            ssm_chunk: int = 64):
    tokens = batch["tokens"]
    logits, _ = forward(params, cfg, qcfg, tokens[:, :-1], seed=seed,
                        remat=remat, ssm_chunk=ssm_chunk)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"nll": loss, "aux": jnp.zeros((), jnp.float32)}
