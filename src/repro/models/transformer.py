"""Decoder-only LM transformer (dense / MoE / VLM-backbone families).

Layers are *stacked* and applied with lax.scan so HLO size (and dry-run
compile time) is O(1) in depth — essential for llama3-405b's 126 layers on a
512-device mesh.  Optional per-layer remat (jax.checkpoint) bounds activation
memory for the train shapes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fqt import QuantConfig
from repro.distributed.sharding import constrain
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig
from repro.models.layers import (QCtx, attn_apply, attn_params, dense_init,
                                 embed_init, make_kv_cache, mlp_apply,
                                 mlp_params, rmsnorm)

_SEED_STRIDE = jnp.uint32(0x9E3779B9)


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    kE, kL, kH = jax.random.split(key, 3)

    def layer_init(k):
        ka, km, kn = jax.random.split(k, 3)
        p = {
            "attn": attn_params(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, cfg.qkv_bias, dtype,
                                qk_norm=cfg.use_qk_norm),
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
        }
        if cfg.n_experts:
            p["moe"] = moe_mod.moe_params(km, cfg, dtype)
        else:
            p["mlp"] = mlp_params(km, cfg.d_model, cfg.d_ff, cfg.act, dtype)
        return p

    layers = jax.vmap(layer_init)(jax.random.split(kL, cfg.n_layers))
    params = {
        "embed": embed_init(kE, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kH, cfg.d_model, cfg.padded_vocab,
                                       dtype)
    return params


def _layer_apply(cfg: ModelConfig, lp, x, seed, *, positions, cache,
                 qcfg: QuantConfig, slot=None, plen=None, pfx=None,
                 write_mask=None):
    ctx = QCtx(qcfg, seed)
    x = constrain(x, "res")
    h, new_cache = attn_apply(
        lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), ctx,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
        rope_theta=cfg.rope_theta, window=cfg.sliding_window,
        chunk=cfg.attn_chunk, positions=positions, cache=cache,
        slot=slot, plen=plen, pfx=pfx, write_mask=write_mask,
        norm_eps=cfg.norm_eps)
    x = x + h
    hin = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        B, S, d = hin.shape
        y2, aux = moe_mod.moe_apply(lp["moe"], hin.reshape(B * S, d), ctx, cfg)
        y = y2.reshape(B, S, d)
    else:
        y = mlp_apply(lp["mlp"], hin, ctx, cfg.act)
        aux = jnp.zeros((), jnp.float32)
    return x + y, new_cache, aux


def apply_layers(params, cfg: ModelConfig, qcfg: QuantConfig, x, seed, *,
                 positions=None, caches=None, remat: bool = False,
                 slot=None, plen=None, pfx=None, write_mask=None):
    """Scan the stacked layers.  Returns (x, new_caches, aux_loss_sum)."""
    L = cfg.n_layers
    seeds = jnp.asarray(seed, jnp.uint32) + jnp.arange(
        L, dtype=jnp.uint32) * _SEED_STRIDE

    def body(x, per_layer):
        lp, s, c = per_layer
        y, nc, aux = _layer_apply(cfg, lp, x, s, positions=positions,
                                  cache=c, qcfg=qcfg, slot=slot, plen=plen,
                                  pfx=pfx, write_mask=write_mask)
        return y, (nc, aux)

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    xs = (params["layers"], seeds, caches)
    x, (new_caches, auxes) = jax.lax.scan(body, x, xs)
    return x, new_caches, jnp.sum(auxes)


def _logits(params, cfg: ModelConfig, qcfg: QuantConfig, x, seed):
    head_cfg = qcfg if cfg.quantize_lm_head else QuantConfig()
    ctx = QCtx(head_cfg, jnp.asarray(seed, jnp.uint32) + jnp.uint32(0xABCDEF))
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = constrain(ctx.dense(x, w), "logits")
    if cfg.padded_vocab != cfg.vocab_size:   # mask padded ids
        neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30,
                       logits.dtype)
        logits = logits.at[..., cfg.vocab_size:].set(neg)
    return logits


def forward(params, cfg: ModelConfig, qcfg: QuantConfig, tokens, *,
            seed=0, prefix_embeds: Optional[jax.Array] = None,
            remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train/prefill).  Returns (logits, aux_loss).

    ``prefix_embeds``: (B, P, d) pre-computed modality embeddings (VLM stub)
    prepended to the token embeddings.
    """
    x = constrain(params["embed"][tokens], "res")
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _, aux = apply_layers(params, cfg, qcfg, x, seed,
                             positions=positions, caches=None, remat=remat)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1]:]
    return _logits(params, cfg, qcfg, x, seed), aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, kv_format: str = "bf16",
               page_size=None, total_pages=None):
    buf = max_len if cfg.sliding_window is None else min(
        max_len, cfg.sliding_window)
    if page_size:                      # paged: round up to whole pages
        buf = -(-buf // page_size) * page_size

    def one(_):
        return make_kv_cache(batch, buf, cfg.n_kv_heads, cfg.hd, dtype,
                             kv_format, page_size=page_size,
                             total_pages=total_pages)

    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def prefill_slot(params, cfg: ModelConfig, qcfg: QuantConfig, tokens,
                 caches, slot, plen, *, seed=0):
    """Prefill ONE paged slot from a right-padded (1, Sp) prompt.

    ``plen`` (dynamic) is the true prompt length; rows in [plen, Sp) are
    pad whose cache writes are masked by the slot length at read time.
    Returns (logits_at_last_prompt_token (1, V), caches)."""
    x = params["embed"][tokens]
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    x, new_caches, _ = apply_layers(params, cfg, qcfg, x, seed,
                                    positions=positions, caches=caches,
                                    remat=False, slot=slot, plen=plen)
    x = jax.lax.dynamic_slice_in_dim(
        x, jnp.asarray(plen, jnp.int32) - 1, 1, axis=1)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _logits(params, cfg, qcfg, x, seed)[:, 0], new_caches


def prefill_suffix(params, cfg: ModelConfig, qcfg: QuantConfig, tokens,
                   caches, slot, plen, pfx, *, seed=0):
    """Prefill ONE paged slot from a right-padded (1, Sp) prompt SUFFIX
    whose prefix of ``pfx`` tokens is already cached in the slot's shared
    pages (warm shared-prefix admission).

    ``plen`` is the TOTAL prompt length (prefix + true suffix); both it
    and ``pfx`` are dynamic scalars, so one compiled program serves every
    warm admission.  Suffix K/V rows are written at logical positions
    [pfx, plen); the queries attend through the paged cache (dequantized
    shared prefix + fresh suffix).  Returns
    (logits_at_last_prompt_token (1, V), caches)."""
    x = params["embed"][tokens]
    positions = (jnp.asarray(pfx, jnp.int32)
                 + jnp.arange(x.shape[1], dtype=jnp.int32))
    x, new_caches, _ = apply_layers(params, cfg, qcfg, x, seed,
                                    positions=positions, caches=caches,
                                    remat=False, slot=slot, plen=plen,
                                    pfx=pfx)
    x = jax.lax.dynamic_slice_in_dim(
        x, jnp.asarray(plen, jnp.int32) - jnp.asarray(pfx, jnp.int32) - 1,
        1, axis=1)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _logits(params, cfg, qcfg, x, seed)[:, 0], new_caches


def prefill_chunk(params, cfg: ModelConfig, qcfg: QuantConfig, tokens,
                  caches, slot, off, *, seed=0):
    """Write ONE full intermediate chunk of a prompt into a paged slot.

    ``tokens`` is a (1, C) chunk of the prompt covering logical positions
    [off, off + C) — always exactly full (the FINAL, possibly short chunk
    goes through ``prefill_suffix``, which also samples the first token).
    ``off`` is a dynamic scalar, so one compiled program serves every
    chunk of every admission.  Reuses the quantize-then-attend suffix
    machinery (write the chunk's quantized K/V rows, then attend through
    the paged cache over [0, off + C)), so each token's hidden state is a
    pure function of the quantized rows before it — the chunk partition
    cannot change any value, and chunked prefill is BIT-identical to an
    unchunked suffix prefill.  No lm_head / no sampling: intermediate
    chunks emit nothing.  Returns the updated caches only."""
    x = params["embed"][tokens]
    C = x.shape[1]
    off = jnp.asarray(off, jnp.int32)
    positions = off + jnp.arange(C, dtype=jnp.int32)
    _, new_caches, _ = apply_layers(params, cfg, qcfg, x, seed,
                                    positions=positions, caches=caches,
                                    remat=False, slot=slot, plen=off + C,
                                    pfx=off)
    return new_caches


def prefill(params, cfg, qcfg, tokens, caches, *, seed=0,
            prefix_embeds=None):
    """Run the prompt through the model, filling caches; returns
    (last_token_logits, caches)."""
    x = params["embed"][tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x, new_caches, _ = apply_layers(params, cfg, qcfg, x, seed,
                                    positions=None, caches=caches,
                                    remat=False)
    x = rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    return _logits(params, cfg, qcfg, x, seed), new_caches


def decode_step(params, cfg: ModelConfig, qcfg: QuantConfig, tokens, caches,
                *, seed=0, write_mask=None):
    """One new token per sequence.  tokens: (B, 1).  Returns (logits, caches).

    ``write_mask`` ((B,) bool, paged caches only): slots mid-chunked-
    prefill write to the trash page and keep their length — see
    ``PagedKVCache.write_token``."""
    x = params["embed"][tokens]
    x, new_caches, _ = apply_layers(params, cfg, qcfg, x, seed,
                                    positions=None, caches=caches,
                                    remat=False, write_mask=write_mask)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _logits(params, cfg, qcfg, x, seed), new_caches


def verify_k(params, cfg: ModelConfig, qcfg: QuantConfig, tokens, caches,
             *, seed=0, write_mask=None):
    """Teacher-forced verify pass over a speculative block: ``tokens`` is
    (B, k) — each slot's last committed token followed by k-1 drafted
    tokens — written into the paged caches at [len, len + k) and attended
    with per-slot causal positions.  Query row j sees exactly the rows
    [0, len + j] a sequential decode of token j would see, and RtN row
    quantization is neighbor-independent, so row j's logits are
    BIT-identical to non-speculative decode — the acceptance check can
    use strict argmax equality.  Rejected rows are rolled back by the
    caller via ``PagedKVCache.truncate_to``.

    ``write_mask`` ((B,) bool): masked-off slots write to the trash page
    and keep their length.  Returns (logits (B, k, V), caches)."""
    x = params["embed"][tokens]
    x, new_caches, _ = apply_layers(params, cfg, qcfg, x, seed,
                                    positions=None, caches=caches,
                                    remat=False, write_mask=write_mask)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _logits(params, cfg, qcfg, x, seed), new_caches


def draft_view(params, caches, draft_layers: int):
    """Self-draft view: the SAME stacked weights (and caches) truncated to
    the first ``draft_layers`` layers.  A pure trace-level slice of the
    leading layer axis — no copy of the packed store persists, so the
    draft model costs zero extra HBM for weights.  Embedding, final norm
    and lm_head are shared as-is.  Pair with
    ``dataclasses.replace(cfg, n_layers=draft_layers)`` so scan sees the
    truncated depth.  Returns (draft_params, draft_caches)."""
    dp = dict(params)
    dp["layers"] = jax.tree_util.tree_map(lambda a: a[:draft_layers],
                                          params["layers"])
    dc = (None if caches is None else
          jax.tree_util.tree_map(lambda a: a[:draft_layers], caches))
    return dp, dc


def loss_fn(params, cfg: ModelConfig, qcfg: QuantConfig, batch, *, seed=0,
            remat: bool = True):
    """Next-token cross-entropy (+ MoE aux).  batch: {tokens, (prefix_embeds)}."""
    tokens = batch["tokens"]
    logits, aux = forward(params, cfg, qcfg, tokens[:, :-1], seed=seed,
                          prefix_embeds=batch.get("prefix_embeds"),
                          remat=remat)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss + cfg.router_aux_weight * aux, {"nll": loss, "aux": aux}
