"""Whisper-style encoder-decoder transformer (audio family).

Per the assignment spec, the conv/mel frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings (B, enc_seq, d) to the encoder.  The
backbone itself is faithful: bidirectional encoder, causal decoder with
cross-attention, GELU FFNs, learned positional embeddings — with every GEMM
routed through the FQT path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.fqt import QuantConfig
from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import (PagedKVCache, QCtx, attn_apply, attn_params,
                                 dense_init, embed_init, make_kv_cache,
                                 mlp_apply, mlp_params, rmsnorm)

_SEED_STRIDE = jnp.uint32(0x9E3779B9)


def _block_params(key, cfg: ModelConfig, cross: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {
        "attn": attn_params(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.hd, bias=True, dtype=dtype),
        "mlp": mlp_params(ks[1], cfg.d_model, cfg.d_ff, "gelu", dtype),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }
    if cross:
        p["xattn"] = attn_params(ks[2], cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.hd, bias=True,
                                 dtype=dtype)
        p["lnx"] = jnp.ones((cfg.d_model,), dtype)
    return p


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    kE, kP, kPe, kEnc, kDec = jax.random.split(key, 5)
    enc = jax.vmap(lambda k: _block_params(k, cfg, False, dtype))(
        jax.random.split(kEnc, cfg.enc_layers))
    dec = jax.vmap(lambda k: _block_params(k, cfg, True, dtype))(
        jax.random.split(kDec, cfg.n_layers))
    return {
        "embed": embed_init(kE, cfg.padded_vocab, cfg.d_model, dtype),
        # sized for the largest assigned decoder context (decode_32k)
        "pos_dec": embed_init(kP, 32768, cfg.d_model, dtype),
        "pos_enc": embed_init(kPe, cfg.enc_seq, cfg.d_model, dtype),
        "enc": enc,
        "dec": dec,
        "ln_enc": jnp.ones((cfg.d_model,), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
    }


def encode(params, cfg: ModelConfig, qcfg: QuantConfig, frames, *, seed=0,
           remat: bool = False):
    """frames: (B, enc_seq, d) precomputed frame embeddings (frontend stub)."""
    x = frames + params["pos_enc"][None, :frames.shape[1]]
    seeds = jnp.asarray(seed, jnp.uint32) + jnp.arange(
        cfg.enc_layers, dtype=jnp.uint32) * _SEED_STRIDE

    def body(x, per_layer):
        lp, s = per_layer
        ctx = QCtx(qcfg, s)
        x = constrain(x, "res")
        h, _ = attn_apply(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps),
                          ctx, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                          hd=cfg.hd, rope_theta=cfg.rope_theta, causal=False,
                          chunk=cfg.attn_chunk, use_rope=False)
        x = x + h
        x = x + mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps),
                          ctx, "gelu")
        return x, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, (params["enc"], seeds))
    return rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def _decoder(params, cfg, qcfg, x, enc_out, seed, *, positions, caches,
             remat=False, slot=None, plen=None):
    seeds = (jnp.asarray(seed, jnp.uint32) + jnp.uint32(0x777)
             + jnp.arange(cfg.n_layers, dtype=jnp.uint32) * _SEED_STRIDE)

    def body(x, per_layer):
        lp, s, c = per_layer
        ctx = QCtx(qcfg, s)
        x = constrain(x, "res")
        h, nc = attn_apply(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps),
                           ctx, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                           hd=cfg.hd, rope_theta=cfg.rope_theta,
                           chunk=cfg.attn_chunk, positions=positions,
                           cache=c, slot=slot, plen=plen, use_rope=False)
        x = x + h
        hx, _ = attn_apply(lp["xattn"], rmsnorm(x, lp["lnx"], cfg.norm_eps),
                           ctx, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                           hd=cfg.hd, rope_theta=cfg.rope_theta,
                           xkv=enc_out, chunk=cfg.attn_chunk, use_rope=False)
        x = x + hx
        x = x + mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps),
                          ctx, "gelu")
        return x, nc

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, new_caches = jax.lax.scan(body, x, (params["dec"], seeds, caches))
    return x, new_caches


def _logits(params, cfg, qcfg, x, seed):
    ctx = QCtx(qcfg if cfg.quantize_lm_head else QuantConfig(),
               jnp.asarray(seed, jnp.uint32) + jnp.uint32(0xABCDEF))
    logits = constrain(ctx.dense(x, params["embed"].T), "logits")  # tied
    if cfg.padded_vocab != cfg.vocab_size:
        neg = jnp.full((cfg.padded_vocab - cfg.vocab_size,), -1e30,
                       logits.dtype)
        logits = logits.at[..., cfg.vocab_size:].set(neg)
    return logits


def forward(params, cfg: ModelConfig, qcfg: QuantConfig, tokens, *,
            frames=None, seed=0, remat: bool = True):
    """Teacher-forced training forward.  tokens: (B,S); frames: (B,T,d)."""
    B, S = tokens.shape
    if frames is None:
        frames = jnp.zeros((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    enc_out = encode(params, cfg, qcfg, frames, seed=seed, remat=remat)
    x = params["embed"][tokens] + params["pos_dec"][None, :S]
    positions = jnp.arange(S, dtype=jnp.int32)
    x, _ = _decoder(params, cfg, qcfg, x, enc_out, seed,
                    positions=positions, caches=None, remat=remat)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _logits(params, cfg, qcfg, x, seed), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, kv_format: str = "bf16",
               page_size=None, total_pages=None):
    buf = max_len
    if page_size:
        buf = -(-buf // page_size) * page_size

    def one(_):
        return make_kv_cache(batch, buf, cfg.n_kv_heads, cfg.hd, dtype,
                             kv_format, page_size=page_size,
                             total_pages=total_pages)
    return jax.vmap(one)(jnp.arange(cfg.n_layers))


def prefill_slot(params, cfg, qcfg, tokens, enc_slot, caches, slot, plen,
                 *, seed=0):
    """Prefill ONE paged decoder slot from a right-padded (1, Sp) prompt
    against that request's encoder output (1, enc_seq, d).  Returns
    (logits_at_last_prompt_token (1, V), caches)."""
    S = tokens.shape[1]
    x = params["embed"][tokens] + params["pos_dec"][None, :S]
    x, new_caches = _decoder(params, cfg, qcfg, x, enc_slot, seed,
                             positions=jnp.arange(S, dtype=jnp.int32),
                             caches=caches, slot=slot, plen=plen)
    x = jax.lax.dynamic_slice_in_dim(
        x, jnp.asarray(plen, jnp.int32) - 1, 1, axis=1)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _logits(params, cfg, qcfg, x, seed)[:, 0], new_caches


def prefill(params, cfg, qcfg, tokens, enc_out, caches, *, seed=0):
    """Run the prompt through the decoder, filling KV caches.

    Returns (last_token_logits, (enc_out, caches))."""
    B, S = tokens.shape
    x = params["embed"][tokens] + params["pos_dec"][None, :S]
    x, new_caches = _decoder(params, cfg, qcfg, x, enc_out, seed,
                             positions=None, caches=caches)
    x = rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    return _logits(params, cfg, qcfg, x, seed), (enc_out, new_caches)


def decode_step(params, cfg, qcfg, tokens, carry, *, seed=0):
    """carry = (enc_out, caches); tokens: (B,1)."""
    enc_out, caches = carry
    if isinstance(caches, PagedKVCache):
        pos0 = caches.lengths[0]       # (B,) per-slot positions (layer 0)
        x = params["embed"][tokens] + params["pos_dec"][pos0][:, None]
    else:
        pos0 = caches.length[0]        # stacked per-layer lengths; all equal
        x = params["embed"][tokens] + params["pos_dec"][pos0][None, None]
    x, new_caches = _decoder(params, cfg, qcfg, x, enc_out, seed,
                             positions=None, caches=caches)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _logits(params, cfg, qcfg, x, seed), (enc_out, new_caches)


def loss_fn(params, cfg, qcfg, batch, *, seed=0, remat=True):
    tokens = batch["tokens"]
    logits, _ = forward(params, cfg, qcfg, tokens[:, :-1],
                        frames=batch.get("frames"), seed=seed, remat=remat)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"nll": loss, "aux": jnp.zeros((), jnp.float32)}
