"""xLSTM: mLSTM (matrix-memory, chunk-parallel) + sLSTM (scalar-memory,
sequential) blocks, per Beck et al. 2024 (arXiv:2405.04517).

Every ``slstm_every``-th block is sLSTM, the rest mLSTM.  All projections
(q/k/v, gates, up/down) are FQT-quantized GEMMs; the recurrent cell math is
elementwise f32 (DESIGN.md §5).

mLSTM runs in a chunkwise-parallel form (gated linear attention with scalar
per-head decay), so training is sub-quadratic and decode carries O(1) state —
xlstm-125m therefore runs the long_500k cell.

Numerics note: the exponential input gate is clamped (preactivation <= 3)
instead of carrying the running-max stabiliser of the reference CUDA kernels;
with the clamp, chunk-local weights are bounded by e^3 and plain f32 exp is
safe.  Real xLSTM implementations clamp similarly before stabilising.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.fqt import QuantConfig
from repro.distributed.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import QCtx, dense_init, embed_init, rmsnorm, swiglu

_SEED_STRIDE = jnp.uint32(0x9E3779B9)
IGATE_CLAMP = 3.0


def _dims(cfg: ModelConfig):
    d_inner = int(cfg.proj_factor * cfg.d_model)
    H = cfg.n_heads
    P = d_inner // H
    return d_inner, H, P


# ---- mLSTM -------------------------------------------------------------------


def mlstm_params(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    d_inner, H, P = _dims(cfg)
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], d, 2 * d_inner, dtype),   # [x arm, gate arm]
        "w_q": dense_init(ks[1], d_inner, d_inner, dtype),
        "w_k": dense_init(ks[2], d_inner, d_inner, dtype),
        "w_v": dense_init(ks[3], d_inner, d_inner, dtype),
        "w_if": dense_init(ks[4], d_inner, 2 * H, dtype, scale=0.01),
        "b_if": jnp.concatenate(
            [jnp.zeros((H,)), 3.0 * jnp.ones((H,))]).astype(jnp.float32),
        "w_down": dense_init(ks[5], d_inner, d, dtype),
        "norm": jnp.ones((d_inner,), dtype),
    }


def _mlstm_chunked(q, k, v, li, lf, chunk: int):
    """q,k,v: (B,S,H,P); li/lf: (B,S,H) log input / log forget gates.

      C_t = f_t C_{t-1} + i_t v_t k_t^T      (C: (P_v, P_k))
      n_t = f_t n_{t-1} + i_t k_t
      y_t = (C_t q_t) / (max(|n_t . q_t|, 1))

    Chunk-parallel: intra-chunk masked-decay attention + lax.scan over chunk
    states.  Returns (y, (C_T, n_T))."""
    B, S, H, P = q.shape
    nc = S // chunk
    qc = q.reshape(B, nc, chunk, H, P).astype(jnp.float32) * (P ** -0.5)
    kc = k.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    vc = v.reshape(B, nc, chunk, H, P).astype(jnp.float32)
    lic = li.reshape(B, nc, chunk, H)
    lfc = lf.reshape(B, nc, chunk, H)
    F = jnp.cumsum(lfc, axis=2)                          # log prod f_1..s

    # intra-chunk weights  w[s,t] = exp(F_s - F_t + li_t),  s >= t
    logw = (F[:, :, :, None, :] - F[:, :, None, :, :]
            + lic[:, :, None, :, :])                     # (B,nc,s,t,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    w = jnp.exp(jnp.where(mask[None, None, :, :, None], logw, -1e30))
    scores = jnp.einsum("bcshp,bcthp->bcsth", qc, kc)    # (B,nc,s,t,H)
    y_intra = jnp.einsum("bcsth,bcsth,bcthp->bcshp", scores, w, vc)
    n_intra = jnp.einsum("bcsth,bcthp->bcshp", w, kc)

    # chunk summaries (contribution of chunk c to the state after chunk c)
    dec_end = jnp.exp(F[:, :, -1:, :] - F + lic)         # (B,nc,t,H)
    C_sum = jnp.einsum("bcth,bcthv,bcthk->bchvk", dec_end, vc, kc)
    n_sum = jnp.einsum("bcth,bcthk->bchk", dec_end, kc)
    chunk_dec = jnp.exp(F[:, :, -1, :])                  # (B,nc,H)

    def body(carry, xs):
        C, n = carry
        Cs, ns, dec = xs
        C_in, n_in = C, n
        C = C * dec[:, :, None, None] + Cs
        n = n * dec[:, :, None] + ns
        return (C, n), (C_in, n_in)

    C0 = jnp.zeros((B, H, P, P), jnp.float32)
    n0 = jnp.zeros((B, H, P), jnp.float32)
    (CT, nT), (C_in, n_in) = jax.lax.scan(
        body, (C0, n0),
        (C_sum.swapaxes(0, 1), n_sum.swapaxes(0, 1), chunk_dec.swapaxes(0, 1)))
    C_in = C_in.swapaxes(0, 1)                           # (B,nc,H,Pv,Pk)
    n_in = n_in.swapaxes(0, 1)                           # (B,nc,H,Pk)

    decf = jnp.exp(F)                                    # (B,nc,s,H)
    y_inter = jnp.einsum("bcshk,bchvk,bcsh->bcshv", qc, C_in, decf)
    n_vec = n_intra + n_in[:, :, None, :, :] * decf[..., None]
    qn = jnp.einsum("bcshk,bcshk->bcsh", qc, n_vec)
    denom = jnp.maximum(jnp.abs(qn), 1.0)
    y = ((y_intra + y_inter) / denom[..., None]).reshape(B, S, H, P)
    return y, (CT, nT)


def mlstm_apply(p, x, ctx: QCtx, cfg: ModelConfig, *, state=None,
                chunk: int = 64):
    """Pre-up-projected mLSTM block.  Returns (y, new_state=(C, n))."""
    B, S, d = x.shape
    d_inner, H, P = _dims(cfg)
    up = constrain(ctx.dense(x, p["w_up"]), "hidden")
    xa, ga = jnp.split(up, 2, axis=-1)                   # (B,S,d_inner) each
    q = constrain(ctx.dense(xa, p["w_q"]).reshape(B, S, H, P), "heads")
    k = constrain(ctx.dense(xa, p["w_k"]).reshape(B, S, H, P), "heads")
    v = constrain(ctx.dense(xa, p["w_v"]).reshape(B, S, H, P), "heads")
    gif = ctx.dense_hp(xa, p["w_if"]).astype(jnp.float32) + p["b_if"]
    gi, gf = jnp.split(gif, 2, axis=-1)                  # (B,S,H)
    li = jnp.minimum(gi, IGATE_CLAMP)                    # log i (clamped exp)
    lf = jax.nn.log_sigmoid(gf)                          # log f

    if state is None:
        c = min(chunk, S)
        if S % c:
            raise ValueError(f"seq {S} not divisible by mlstm chunk {c}")
        y, new_state = _mlstm_chunked(q, k, v, li, lf, c)
    else:
        C, n = state
        i = jnp.exp(li[:, 0])                            # (B,H)
        f = jnp.exp(lf[:, 0])
        q0 = q[:, 0].astype(jnp.float32) * (P ** -0.5)
        k0 = k[:, 0].astype(jnp.float32)
        v0 = v[:, 0].astype(jnp.float32)
        C = C * f[..., None, None] + i[..., None, None] * jnp.einsum(
            "bhv,bhk->bhvk", v0, k0)
        n = n * f[..., None] + i[..., None] * k0
        num = jnp.einsum("bhk,bhvk->bhv", q0, C)
        qn = jnp.einsum("bhk,bhk->bh", q0, n)
        y = (num / jnp.maximum(jnp.abs(qn), 1.0)[..., None])[:, None]
        new_state = (C, n)

    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(ga.astype(jnp.float32)).astype(x.dtype)
    return ctx.dense(y, p["w_down"]), new_state


# ---- sLSTM -------------------------------------------------------------------


def slstm_params(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    f = int(cfg.proj_factor * d)
    ks = jax.random.split(key, 4)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),
        "r_gates": dense_init(ks[1], d, 4 * d, dtype, scale=0.01),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "w_ff_gate": dense_init(ks[2], d, f, dtype),
        "w_ff_up": dense_init(ks[2], d, f, dtype),
        "w_ff_down": dense_init(ks[3], f, d, dtype),
        "norm": jnp.ones((d,), dtype),
    }


def slstm_apply(p, x, ctx: QCtx, cfg: ModelConfig, *, state=None):
    """Sequential sLSTM with exponential gating + stabiliser state.

    state: (c, n, h, m) each (B, d).  Train: lax.scan over time (the input
    GEMM is hoisted out of the scan and FQT-quantized; the tiny recurrent
    matvec stays bf16).  Returns (y, new_state)."""
    B, S, d = x.shape
    gates_in = ctx.dense(x, p["w_gates"])                # (B,S,4d) quantized

    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        state = (c0, c0, c0, c0 - 10.0)

    def step(carry, gin):
        c, n, h, m = carry
        pre = (gin.astype(jnp.float32) + p["b_gates"]
               + ctx.dense_hp(h.astype(x.dtype), p["r_gates"]
                              ).astype(jnp.float32))
        z, i, f, o = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        logf = jax.nn.log_sigmoid(f)
        m_new = jnp.maximum(logf + m, i)                  # stabiliser
        ip = jnp.exp(i - m_new)
        fp = jnp.exp(logf + m - m_new)
        c = fp * c + ip * z
        n = fp * n + ip
        h = o * (c / jnp.maximum(n, 1.0))
        return (c, n, h, m_new), h

    (c, n, h, m), hs = jax.lax.scan(step, state,
                                    gates_in.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)                # (B,S,d)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    g = constrain(ctx.dense(y, p["w_ff_gate"]), "hidden")
    u = constrain(ctx.dense(y, p["w_ff_up"]), "hidden")
    y = ctx.dense(swiglu(g, u), p["w_ff_down"])
    return y, (c, n, h, m)


# ---- backbone ------------------------------------------------------------------


def _is_slstm(cfg: ModelConfig, layer: int) -> bool:
    return bool(cfg.slstm_every) and (layer + 1) % cfg.slstm_every == 0


def init(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = []
    for l in range(cfg.n_layers):
        if _is_slstm(cfg, l):
            layers.append({"slstm": slstm_params(ks[l], cfg, dtype)})
        else:
            layers.append({"mlstm": mlstm_params(ks[l], cfg, dtype)})
    return {
        "embed": embed_init(ks[-3], cfg.padded_vocab, cfg.d_model, dtype),
        "layers": layers,
        "ln": jnp.ones((cfg.n_layers, cfg.d_model), dtype),
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[-2], cfg.d_model, cfg.padded_vocab, dtype),
    }


def init_state(cfg: ModelConfig, batch: int):
    d_inner, H, P = _dims(cfg)
    states = []
    for l in range(cfg.n_layers):
        if _is_slstm(cfg, l):
            z = jnp.zeros((batch, cfg.d_model), jnp.float32)
            states.append((z, z, z, z - 10.0))
        else:
            states.append((jnp.zeros((batch, H, P, P), jnp.float32),
                           jnp.zeros((batch, H, P), jnp.float32)))
    return states


def _backbone(params, cfg, qcfg, x, seed, *, states, remat=False,
              chunk: int = 64):
    """Python-loop over heterogeneous blocks (12 layers: HLO stays small)."""
    new_states = []
    for l, lp in enumerate(params["layers"]):
        ctx = QCtx(qcfg, jnp.asarray(seed, jnp.uint32)
                   + jnp.uint32(l) * _SEED_STRIDE)
        st = states[l] if states is not None else None
        x = constrain(x, "res")
        xin = rmsnorm(x, params["ln"][l], cfg.norm_eps)

        def block(xin, st, lp=lp, ctx=ctx):
            if "slstm" in lp:
                return slstm_apply(lp["slstm"], xin, ctx, cfg, state=st)
            return mlstm_apply(lp["mlstm"], xin, ctx, cfg, state=st,
                               chunk=chunk)

        if remat and states is None:
            block = jax.checkpoint(
                block, policy=jax.checkpoint_policies.nothing_saveable)
        y, ns = block(xin, st)
        x = x + y
        new_states.append(ns)
    return x, new_states


def forward(params, cfg: ModelConfig, qcfg: QuantConfig, tokens, *, seed=0,
            remat: bool = True, chunk: int = 64):
    x = constrain(params["embed"][tokens], "res")
    x, _ = _backbone(params, cfg, qcfg, x, seed, states=None, remat=remat,
                     chunk=chunk)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    ctx = QCtx(qcfg if cfg.quantize_lm_head else QuantConfig(),
               jnp.asarray(seed, jnp.uint32) + jnp.uint32(0xABCDEF))
    return (constrain(ctx.dense(x, params["lm_head"]), "logits"),
            jnp.zeros((), jnp.float32))


def decode_step(params, cfg, qcfg, tokens, states, *, seed=0):
    x = params["embed"][tokens]
    x, new_states = _backbone(params, cfg, qcfg, x, seed, states=states)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    ctx = QCtx(qcfg if cfg.quantize_lm_head else QuantConfig(),
               jnp.asarray(seed, jnp.uint32) + jnp.uint32(0xABCDEF))
    return ctx.dense(x, params["lm_head"]), new_states


def loss_fn(params, cfg, qcfg, batch, *, seed=0, remat=True, chunk=64):
    tokens = batch["tokens"]
    logits, _ = forward(params, cfg, qcfg, tokens[:, :-1], seed=seed,
                        remat=remat, chunk=chunk)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"nll": loss, "aux": jnp.zeros((), jnp.float32)}
