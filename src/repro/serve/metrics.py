"""Simulated-clock serving metrics: TTFT/TPOT/goodput in scheduler TICKS.

Every timestamp in this module is a scheduler tick index — there is no
wall clock anywhere, so recorded trajectories are deterministic and
byte-comparable across runs/machines (the same discipline as the rest of
the serve layer).  ``ContinuousEngine.run`` drives a ``MetricsRecorder``
through the request lifecycle:

    submitted  -> request entered the trace (arrival tick, optional
                  deadline)
    admitted   -> first placed into a device slot
    first_token-> the request's FIRST token reached the host (commit);
                  preemption replays the identical stream, so the first
                  emission is the one the client saw — re-admissions
                  never move it
    finished   -> all tokens committed (EOS or max_new)
    cancelled  -> hard abort/timeout (stage: queued/prefill/decode)

Definitions (all in ticks):

    TTFT     = first_token_tick - arrival        (time to first token)
    TPOT     = (finish_tick - first_token_tick) / max(1, n_tokens - 1)
               (mean time per output token after the first)
    goodput  = completions at-or-before their deadline / submitted
               (requests without a deadline count as on-time when done)

Percentiles are NEAREST-RANK (no interpolation): deterministic, and a
reported p99 is always a latency some request actually experienced.

Host-side and numpy-only, like the scheduler — usable from
``tools/check_env.py --traffic`` without touching the accelerator stack.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs.trace import Counters, NULL_TRACER

PERCENTILES = (50, 95, 99)


def percentile(values: Sequence[float], p: float) -> float:
    """Nearest-rank percentile: the ceil(p/100 * n)-th smallest value.
    Returns NaN on an empty sample (JSON-safe via ``summary``)."""
    v = sorted(float(x) for x in values)
    if not v:
        return float("nan")
    if not (0 < p <= 100):
        raise ValueError(f"percentile p must be in (0, 100], got {p}")
    idx = int(np.ceil(p / 100.0 * len(v))) - 1
    return v[max(0, min(idx, len(v) - 1))]


def percentile_summary(values: Sequence[float],
                       pcts: Sequence[int] = PERCENTILES) -> Dict[str, float]:
    """{p50: ..., p95: ..., p99: ..., mean, max, n} for one metric."""
    out = {f"p{p}": percentile(values, p) for p in pcts}
    out["mean"] = float(np.mean(values)) if len(values) else float("nan")
    out["max"] = float(max(values)) if len(values) else float("nan")
    out["n"] = len(values)
    return out


class MetricsRecorder:
    """Per-request lifecycle timestamps + per-tick gauges, summarized to
    percentile dictionaries.  One recorder per ``run()`` trace.

    Counter state lives on the obs-layer ``Counters`` substrate
    (obs/trace.py) — the same primitive a ``Tracer`` accumulates into —
    and when a tracer is attached every lifecycle event is mirrored into
    the trace: lifecycle counters, first-token instants, queue/active
    gauges per tick.  ``summary()`` shapes are unchanged (``Counters`` is
    mapping-like, so ``dict(self.counters)`` still snapshots it)."""

    def __init__(self, tracer=None):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.requests: Dict[int, dict] = {}
        self.queue_depth: List[int] = []       # gauge, one entry per tick
        self.active_depth: List[int] = []      # decoding slots per tick
        self.counters = Counters()             # scheduler stats snapshot
        self.lifecycle = Counters()            # own event tallies
        # speculative decoding (one sample per SLOT per verify tick):
        # tokens the verify emitted for that slot (accepted prefix + the
        # corrected token, 1..k) and its acceptance rate (accepted
        # drafts / (k-1) proposed)
        self.spec_accepted: List[int] = []
        self.spec_rate: List[float] = []

    # ---- lifecycle events ----------------------------------------------

    def submitted(self, rid: int, arrival: int,
                  deadline: Optional[int] = None) -> None:
        self.requests[rid] = {"arrival": int(arrival),
                              "deadline": deadline,
                              "admitted": None, "first": None,
                              "done": None, "ntokens": 0,
                              "cancelled": None}
        self.lifecycle.inc("submitted")
        self.tracer.counter("met_submitted", ts=int(arrival))

    def admitted(self, rid: int, tick: int) -> None:
        r = self.requests[rid]
        if r["admitted"] is None:       # re-admission after preemption
            r["admitted"] = int(tick)   # keeps the FIRST placement tick
            self.lifecycle.inc("admitted")

    def first_token(self, rid: int, tick: int) -> None:
        r = self.requests[rid]
        if r["first"] is None:          # preemption replays the identical
            r["first"] = int(tick)      # stream; the first emission stands
            self.lifecycle.inc("first_tokens")
            self.tracer.instant(f"req:{rid}", "first_token", ts=int(tick),
                                ttft=int(tick) - r["arrival"])

    def finished(self, rid: int, tick: int, ntokens: int) -> None:
        r = self.requests[rid]
        r["done"] = int(tick)
        r["ntokens"] = int(ntokens)
        self.lifecycle.inc("finished")
        self.tracer.counter("met_finished", ts=int(tick))

    def cancelled(self, rid: int, tick: int, stage: str,
                  reason: str) -> None:
        self.requests[rid]["cancelled"] = {"tick": int(tick),
                                           "stage": stage,
                                           "reason": reason}
        self.lifecycle.inc("cancelled")
        self.tracer.counter("met_cancelled", ts=int(tick))

    # ---- per-tick gauges / counters ------------------------------------

    def tick(self, queue_depth: int, n_active: int) -> None:
        self.queue_depth.append(int(queue_depth))
        self.active_depth.append(int(n_active))
        self.tracer.gauge("queue_depth", int(queue_depth))
        self.tracer.gauge("active_slots", int(n_active))

    def spec_tick(self, emitted: Sequence[int], k: int) -> None:
        """One speculative verify tick: ``emitted`` holds the per-slot
        token counts the verify emitted (accepted prefix + corrected
        token — 1..k each) for the slots that decoded this tick.  The
        accepted-tokens/tick/slot trajectory is ``emitted`` itself; the
        acceptance rate divides the accepted DRAFTS (emitted - 1) by the
        k-1 proposed."""
        for n in emitted:
            self.spec_accepted.append(int(n))
            self.spec_rate.append((int(n) - 1) / max(1, k - 1))
        if emitted:
            self.tracer.counter("spec_emitted_tokens",
                                sum(int(n) for n in emitted))

    def set_counters(self, stats: Dict[str, int]) -> None:
        self.counters = Counters({k: int(v) for k, v in stats.items()})

    # ---- summaries -----------------------------------------------------

    def ttfts(self) -> List[int]:
        return [r["first"] - r["arrival"] for r in self.requests.values()
                if r["first"] is not None]

    def tpots(self) -> List[float]:
        return [(r["done"] - r["first"]) / max(1, r["ntokens"] - 1)
                for r in self.requests.values()
                if r["done"] is not None and r["first"] is not None]

    def goodput(self) -> float:
        if not self.requests:
            return 0.0
        good = sum(1 for r in self.requests.values()
                   if r["done"] is not None
                   and (r["deadline"] is None
                        or r["done"] <= r["deadline"]))
        return good / len(self.requests)

    def summary(self) -> dict:
        done = [r for r in self.requests.values() if r["done"] is not None]
        canc = [r for r in self.requests.values()
                if r["cancelled"] is not None]
        out = {
            "ticks": len(self.queue_depth),
            "submitted": len(self.requests),
            "completed": len(done),
            "cancelled": len(canc),
            "goodput": self.goodput(),
            "ttft_ticks": percentile_summary(self.ttfts()),
            "tpot_ticks": percentile_summary(self.tpots()),
            "queue_depth": percentile_summary(self.queue_depth),
            "active_slots": percentile_summary(self.active_depth),
            "counters": dict(self.counters),
        }
        if self.spec_accepted:
            out["spec_accepted_per_tick_slot"] = percentile_summary(
                self.spec_accepted)
            out["spec_acceptance_rate"] = percentile_summary(self.spec_rate)
        return out
