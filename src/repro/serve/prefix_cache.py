"""Exact shared-prefix cache over the paged NVFP4 KV pool.

Serving heavy multi-user traffic means most requests share long prompt
prefixes — system prompts, few-shot templates, chat history.  Because
cache rows are quantized at write time with deterministic RtN (the
paper's forward rounding) and K/V at position ``i`` depend causally only
on tokens ``<= i``, identical prefix tokens produce **bit-identical
quantized pages** — so prefix reuse is *exact* storage sharing, not an
approximation: a warm slot's decode reads the very same packed rows a
cold slot would have written.

Structure: a hash-block RADIX TREE keyed on full-page token chunks.
Each node covers exactly ``page_size`` tokens and maps that chunk (in
its prefix context — the path from the root) to one physical page of the
shared pool (``scheduler.PagePool``).  The tree holds one refcount on
every cached page; slots that share a page hold additional refcounts.
A page whose refcount has dropped back to the tree's own reference is
*evictable*; eviction is LRU over evictable leaves (leaf-first, so an
ancestor is never removed under a live descendant and every cached
prefix remains reachable from the root).

Nothing here touches jax: matching/insertion/eviction are host-side
scheduler-tick decisions, like the rest of ``serve/scheduler.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.trace import NULL_TRACER


@dataclasses.dataclass
class _Node:
    """One full page of tokens in its prefix context."""
    chunk: Tuple[int, ...]                 # the page_size tokens it covers
    page: int                              # physical page in the pool
    parent: Optional["_Node"]
    children: Dict[Tuple[int, ...], "_Node"] = dataclasses.field(
        default_factory=dict)
    last_used: int = 0                     # LRU clock at last match/insert


class PrefixCache:
    """Radix tree mapping full-page prompt prefixes to physical pages.

    ``pool`` is the shared ``scheduler.PagePool``; the cache owns one
    reference per cached page (taken at ``insert``, released at
    eviction).  ``max_pages`` bounds the number of cached pages —
    inserts beyond it evict least-recently-used evictable nodes first
    (``None``: bounded only by pool pressure via ``evict``).
    """

    def __init__(self, pool, page_size: int,
                 max_pages: Optional[int] = None, tracer=None):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        if max_pages is not None and max_pages < 1:
            raise ValueError("max_pages must be >= 1 (or None)")
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.pool = pool
        self.page_size = page_size
        self.max_pages = max_pages
        self._root: Dict[Tuple[int, ...], _Node] = {}
        self._n_nodes = 0
        self._clock = 0
        self.stats = {"hits": 0, "misses": 0, "hit_pages": 0,
                      "inserted": 0, "evicted": 0}

    # ---- introspection ---------------------------------------------------

    @property
    def cached_pages(self) -> int:
        return self._n_nodes

    def _iter_nodes(self):
        stack = list(self._root.values())
        while stack:
            nd = stack.pop()
            yield nd
            stack.extend(nd.children.values())

    # ---- lookup ----------------------------------------------------------

    def match(self, tokens) -> List[int]:
        """Longest cached full-page prefix of ``tokens`` -> physical pages.

        Touches the matched path (LRU) but takes NO pool references and
        records NO hit/miss stats — the caller (scheduler admission) refs
        the pages it actually uses and calls ``count`` once per PLACED
        request (a blocked request may re-match every tick, and the
        plen-1 cap can drop a match to zero shared pages).
        """
        toks = np.asarray(tokens).tolist()
        self._clock += 1
        pages: List[int] = []
        level = self._root
        for i in range(len(toks) // self.page_size):
            chunk = tuple(toks[i * self.page_size:(i + 1) * self.page_size])
            nd = level.get(chunk)
            if nd is None:
                break
            nd.last_used = self._clock
            pages.append(nd.page)
            level = nd.children
        return pages

    def count(self, shared_pages: int) -> None:
        """Record one admission outcome: a hit iff it actually shared
        pages (after the scheduler's plen-1 cap)."""
        if shared_pages:
            self.stats["hits"] += 1
            self.stats["hit_pages"] += shared_pages
            self.tracer.counter("prefix_hits")
            self.tracer.counter("prefix_hit_pages", shared_pages)
        else:
            self.stats["misses"] += 1
            self.tracer.counter("prefix_misses")

    # ---- insertion -------------------------------------------------------

    def insert(self, tokens, pages) -> int:
        """Register every full-page chunk of ``tokens``; ``pages[i]`` is the
        physical page holding chunk ``i`` (a slot's page-table row).

        Chunks already cached are only touched (their existing page wins —
        contents are bit-identical by the RtN determinism argument); new
        chunks take one pool reference on the slot's page, so the page
        outlives the slot and becomes evictable once no slot shares it.
        Returns the number of newly cached pages.
        """
        toks = np.asarray(tokens).tolist()
        self._clock += 1
        added = 0
        level, parent = self._root, None
        for i in range(len(toks) // self.page_size):
            chunk = tuple(toks[i * self.page_size:(i + 1) * self.page_size])
            nd = level.get(chunk)
            if nd is None:
                page = int(pages[i])
                self.pool.ref(page)
                nd = _Node(chunk, page, parent, last_used=self._clock)
                level[chunk] = nd
                self._n_nodes += 1
                self.stats["inserted"] += 1
                added += 1
            else:
                nd.last_used = self._clock
            level, parent = nd.children, nd
        if added:
            self.tracer.counter("prefix_inserted_pages", added)
        if self.max_pages is not None and self._n_nodes > self.max_pages:
            self.evict(self._n_nodes - self.max_pages)
        return added

    # ---- eviction --------------------------------------------------------

    def _evictable(self, nd: _Node) -> bool:
        # leaf-first: never drop an ancestor under a live descendant;
        # refcount 1 == only the cache itself still holds the page
        return not nd.children and self.pool.refcount(nd.page) == 1

    def evict(self, n: int) -> int:
        """Release up to ``n`` pages back to the pool, LRU-first over
        evictable (refcount-only-ours, childless) nodes.  Returns the
        number actually freed — fewer when live slots pin the rest.

        One tree walk seeds a heap of evictable leaves; removing a leaf
        pushes its parent once it becomes childless and unpinned, so a
        whole cold chain drains in one call without re-walking."""
        import heapq
        if n <= 0:
            return 0
        heap = [(nd.last_used, nd.page, nd) for nd in self._iter_nodes()
                if self._evictable(nd)]
        heapq.heapify(heap)
        freed = 0
        while freed < n and heap:
            _, _, victim = heapq.heappop(heap)
            level = (victim.parent.children if victim.parent is not None
                     else self._root)
            del level[victim.chunk]
            self._n_nodes -= 1
            self.pool.free([victim.page])
            self.stats["evicted"] += 1
            freed += 1
            parent = victim.parent
            if parent is not None and self._evictable(parent):
                heapq.heappush(heap, (parent.last_used, parent.page, parent))
        if freed:
            self.tracer.counter("pages_evicted", freed)
            self.tracer.instant("prefix_cache", "lru_evict", n=freed)
        return freed
