"""Request-level scheduler for continuous batching (host-side, pure Python).

The serving stack splits into two layers:

  * THIS module — everything request-shaped and dynamic: the admission
    queue, the shared page pool, per-slot sequence state (request id,
    prompt length, tokens generated, per-request sampling stream), slot
    free/reuse on EOS/max_new.  Nothing here touches jax; decisions are
    made once per scheduler TICK, not per token.
  * ``serve/engine.ContinuousEngine`` — exactly two jitted programs with
    static shapes (prefill-into-slot, batched decode over all slots) whose
    dynamic state (page table, per-slot lengths, request ids) lives in
    device operands, so admission into a freed slot never recompiles.

Paging: a request needs ``ceil((plen + max_new) / page_size)`` pages for
its whole lifetime, reserved at admission — so the jitted decode loop
never allocates, and admission is simply "a slot is free AND the pool has
enough pages".  Physical page 0 is the TRASH page (layers.TRASH_PAGE):
freed slots' table rows point at it, which lets the static decode program
keep writing for inactive slots without corrupting reallocated pages.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.models.layers import TRASH_PAGE


@dataclasses.dataclass
class Request:
    """One generation request.  ``arrival`` is a scheduler tick index, so
    traces are deterministic (no wall-clock anywhere)."""
    rid: int
    prompt: np.ndarray
    max_new: int = 32
    arrival: int = 0


@dataclasses.dataclass
class SlotState:
    """Device-slot bookkeeping for one admitted request."""
    rid: int
    plen: int
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.tokens)


class PagePool:
    """Free-list allocator over the physical page pool (page 0 = trash)."""

    def __init__(self, total_pages: int):
        if total_pages < 2:
            raise ValueError("page pool needs >= 2 pages (1 is the trash "
                             "page)")
        self._free = list(range(total_pages - 1, 0, -1))   # LIFO; skip trash

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p == TRASH_PAGE:
                raise ValueError("cannot free the trash page")
        self._free.extend(pages)


class Scheduler:
    """Admission queue + slot/page lifecycle for the continuous engine.

    The engine drives it tick by tick:
      1. ``submit`` requests (any time; ``arrival`` gates admission);
      2. ``admit(tick)`` -> [(slot, Request, page_row)] newly placed
         requests (the engine prefills each into its slot);
      3. decode for ``tick_steps()`` steps, then feed the emitted tokens
         back via ``commit(slot, toks)``;
      4. finished slots are released (pages back to the pool) and show up
         as results.
    """

    def __init__(self, n_slots: int, max_len: int, page_size: int,
                 total_pages: Optional[int] = None,
                 slot_pages: Optional[int] = None):
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        # page-table row width: SWA caches roll within min(max_len, window)
        # logical tokens, so the engine passes its (smaller) row width in
        self.n_pages_slot = slot_pages or -(-max_len // page_size)
        if total_pages is None:
            total_pages = 1 + n_slots * self.n_pages_slot
        if total_pages - 1 < self.n_pages_slot:
            raise ValueError(
                f"page pool ({total_pages}) cannot hold even one full "
                f"slot reservation ({self.n_pages_slot} pages)")
        self.pool = PagePool(total_pages)
        self.total_pages = total_pages
        self.queue: deque = deque()
        self.slots: List[Optional[SlotState]] = [None] * n_slots
        self._held: Dict[int, List[int]] = {}          # slot -> pages
        self.results: Dict[int, np.ndarray] = {}
        # counters for the throughput bench / tests
        self.stats = {"admitted": 0, "completed": 0, "decode_steps": 0,
                      "slot_steps": 0, "active_slot_steps": 0}

    # ---- submission / admission -----------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds max_len {self.max_len}")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        self.queue.append(req)

    def admit(self, tick: int) -> List[Tuple[int, Request, np.ndarray]]:
        """Place queued requests (arrival <= tick) into free slots while
        the pool can reserve their pages.  FIFO head-of-line: the queue is
        not reordered around a request that doesn't fit yet."""
        placed = []
        for slot in range(self.n_slots):
            if not self.queue or self.slots[slot] is not None:
                continue
            req = self.queue[0]
            if req.arrival > tick:
                break
            # SWA slots roll: a request never touches more than the slot's
            # own page row, however long it runs
            need = min(-(-(len(req.prompt) + req.max_new) // self.page_size),
                       self.n_pages_slot)
            pages = self.pool.alloc(need)
            if pages is None:
                break
            self.queue.popleft()
            self.slots[slot] = SlotState(req.rid, len(req.prompt),
                                         req.max_new)
            self._held[slot] = pages
            row = np.full((self.n_pages_slot,), TRASH_PAGE, np.int32)
            row[:need] = pages
            self.stats["admitted"] += 1
            placed.append((slot, req, row))
        return placed

    # ---- decode bookkeeping ----------------------------------------------

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def tick_steps(self, chunk: int,
                   pending: Optional[Dict[int, int]] = None) -> int:
        """Decode steps this tick: bounded by the tightest remaining
        budget so no active slot ever writes past its page reservation.
        ``pending``: per-slot tokens already emitted but not yet committed
        (the engine's prefill-sampled first tokens) — they count against
        the budget."""
        pending = pending or {}
        rem = [s.remaining - pending.get(i, 0)
               for i, s in enumerate(self.slots) if s is not None]
        return min([chunk] + rem) if rem else 0

    def commit(self, slot: int, toks: np.ndarray, eos_id: int) -> None:
        """Feed one tick's emitted tokens for ``slot``; finishes the slot
        on EOS or exhausted budget (pages return to the pool)."""
        st = self.slots[slot]
        for t in toks:
            if st.done:
                break
            st.tokens.append(int(t))
            if int(t) == eos_id or len(st.tokens) >= st.max_new:
                st.done = True
        if st.done:
            self.results[st.rid] = np.asarray(st.tokens, np.int32)
            self.pool.free(self._held.pop(slot))
            self.slots[slot] = None
            self.stats["completed"] += 1

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def count_tick(self, steps: int, n_active: Optional[int] = None) -> None:
        """``n_active``: slots that were active DURING the tick (the caller
        snapshots it before commits free finished slots)."""
        if n_active is None:
            n_active = len(self.active_slots())
        self.stats["decode_steps"] += steps
        self.stats["slot_steps"] += steps * self.n_slots
        self.stats["active_slot_steps"] += steps * n_active

    @property
    def slot_utilization(self) -> float:
        """Active-slot decode steps / total slot-steps spent."""
        tot = self.stats["slot_steps"]
        return self.stats["active_slot_steps"] / tot if tot else 0.0
