"""Request-level scheduler for continuous batching (host-side, pure Python).

The serving stack splits into two layers:

  * THIS module — everything request-shaped and dynamic: the admission
    queue, the shared refcounted page pool, per-slot sequence state
    (request id, prompt length, tokens generated, per-request sampling
    stream), slot free/reuse on EOS/max_new, demand-driven page growth
    with deterministic preemption, and the exact shared-prefix cache
    (serve/prefix_cache.py).  Nothing here touches jax; decisions are
    made once per scheduler TICK, not per token.
  * ``serve/engine.ContinuousEngine`` — a fixed set of jitted programs
    with static shapes (prefill-into-slot, suffix prefill for warm
    prefixes, chunked prefill, batched decode over all slots, and the
    speculative verify-k) whose dynamic state (page table, per-slot
    lengths, request ids) lives in device operands, so admission into a
    freed slot never recompiles.

Paging is DEMAND-DRIVEN (vLLM-style): admission allocates only the
pages covering the prompt — ``ceil(plen / page_size)`` minus whatever a
prefix-cache hit shares — and each decode tick grows every active slot
just far enough for that tick's writes (``Scheduler.ensure_capacity``).
On pool exhaustion the scheduler first evicts LRU refcount-0 prefix-
cache pages, then PREEMPTS the youngest active slot (its private pages
return to the pool, its request requeues at the head of the FIFO —
deterministic, and with per-request sampling streams the re-run
regenerates the identical token stream).  With the prefix cache on,
preemption is PARTIAL-SUFFIX: the victim's full written pages are
adopted by the prefix cache and the request requeues with its effective
prompt (original + generated so far), so re-admission recomputes at
most one partial page instead of the whole stream.  Physical page 0 is the TRASH
page (layers.TRASH_PAGE): freed slots' table rows point at it, which
lets the static decode program keep writing for inactive slots without
corrupting reallocated pages.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.models.layers import TRASH_PAGE
from repro.obs.trace import NULL_TRACER
from repro.serve.prefix_cache import PrefixCache


@dataclasses.dataclass
class Request:
    """One generation request.  Every time field is a scheduler TICK
    index, so traces are deterministic (no wall-clock anywhere).

      * ``deadline``: soft completion SLO (absolute tick) — goodput
        metrics count completions at or before it; nothing is cancelled;
      * ``abort_at``: hard client abort — the request is cancelled at
        this tick whatever stage it is in (queued, mid-chunked-prefill,
        decoding, preempted-and-requeued);
      * ``timeout``: hard cancel ``timeout`` ticks after ``arrival`` if
        not finished by then.
    """
    rid: int
    prompt: np.ndarray
    max_new: int = 32
    arrival: int = 0
    deadline: Optional[int] = None
    abort_at: Optional[int] = None
    timeout: Optional[int] = None


@dataclasses.dataclass
class SlotState:
    """Device-slot bookkeeping for one admitted request."""
    rid: int
    plen: int
    max_new: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    written: int = 0        # cache rows written so far (prefill + decode)
    prefill_pos: int = 0    # prompt rows already in the cache (chunked
                            # prefill; == plen once prefill is complete)

    @property
    def prefilling(self) -> bool:
        return self.prefill_pos < self.plen

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.tokens)


class PagePool:
    """Refcounted allocator over the physical page pool (page 0 = trash).

    ``alloc`` hands out pages at refcount 1; ``ref`` adds a holder (a
    slot sharing a cached prefix page, or the prefix cache adopting a
    slot's page); ``free`` drops one reference per page and returns the
    page to the free list only when nobody holds it.  Double-frees and
    out-of-range ids raise — silent acceptance masks page-table
    corruption (a freed page reused by another slot while a stale row
    still points at it).
    """

    def __init__(self, total_pages: int):
        if total_pages < 2:
            raise ValueError("page pool needs >= 2 pages (1 is the trash "
                             "page)")
        self.total_pages = total_pages
        self._free = list(range(total_pages - 1, 0, -1))   # LIFO; skip trash
        self._refs: Dict[int, int] = {}                    # page -> holders

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return len(self._refs)

    def _check(self, page: int) -> None:
        if page == TRASH_PAGE:
            raise ValueError("cannot free/ref the trash page")
        if not (0 < page < self.total_pages):
            raise ValueError(f"page {page} out of range "
                             f"(pool has {self.total_pages} pages)")

    def refcount(self, page: int) -> int:
        self._check(page)
        return self._refs.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def ref(self, page: int) -> None:
        self._check(page)
        if page not in self._refs:
            raise ValueError(f"page {page} is not allocated (cannot add a "
                             f"reference to a free page)")
        self._refs[page] += 1

    def free(self, pages: List[int]) -> None:
        for p in pages:
            self._check(p)
            if p not in self._refs:
                raise ValueError(f"double free of page {p} (not allocated)")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)


class Scheduler:
    """Admission queue + slot/page lifecycle for the continuous engine.

    The engine drives it tick by tick:
      1. ``submit`` requests (any time; ``arrival`` gates admission);
      2. ``admit(tick)`` -> [(slot, Request, page_row, pfx)] newly placed
         requests; ``pfx`` is the shared-prefix token count (0 = cold) —
         the engine prefills only the suffix;
      3. ``ensure_capacity(T)`` grows page rows for the tick's decode
         writes (may evict cached pages / preempt the youngest slot);
      4. decode for ``tick_steps()`` steps, then feed the emitted tokens
         back via ``commit(slot, toks)``;
      5. finished slots are released (pages back to the pool — shared
         pages stay alive while the prefix cache or other slots hold
         them) and show up as results.
    """

    def __init__(self, n_slots: int, max_len: int, page_size: int,
                 total_pages: Optional[int] = None,
                 slot_pages: Optional[int] = None,
                 prefix_cache: bool = False,
                 prefix_cache_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 tracer=None):
        # host-side telemetry (obs/trace.py) — NULL_TRACER when untraced.
        # One span per request (submit -> done/cancelled), instants for
        # admit/preempt/prefill chunks, counters for page movements.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        # chunked prefill: a prompt enters the cache ``prefill_chunk``
        # tokens per TICK (``prefill_work``) instead of all at once at
        # admission, so one long prompt never stalls a decode tick by
        # more than one chunk.  None = prefill everything at admission.
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        # page-table row width: SWA caches roll within min(max_len, window)
        # logical tokens, so the engine passes its (smaller) row width in
        self.n_pages_slot = slot_pages or -(-max_len // page_size)
        if total_pages is None:
            total_pages = 1 + n_slots * self.n_pages_slot
        if total_pages - 1 < self.n_pages_slot:
            raise ValueError(
                f"page pool ({total_pages}) cannot hold even one full "
                f"slot reservation ({self.n_pages_slot} pages)")
        self.pool = PagePool(total_pages)
        self.total_pages = total_pages
        self.prefix_cache: Optional[PrefixCache] = (
            PrefixCache(self.pool, page_size, prefix_cache_pages,
                        tracer=self.tracer)
            if prefix_cache else None)
        self.queue: deque = deque()
        self.slots: List[Optional[SlotState]] = [None] * n_slots
        self._held: Dict[int, List[int]] = {}      # slot -> referenced pages
        self._rows: Dict[int, np.ndarray] = {}     # slot -> page-table row
        self._npages: Dict[int, int] = {}          # slot -> allocated pages
        self._reqs: Dict[int, Request] = {}        # slot -> live Request
        self._adm_seq: Dict[int, int] = {}         # slot -> admission seq
        self._seq = 0
        # chunked mode: prefix-cache insertion is DEFERRED until a slot's
        # final chunk is issued (its pages hold nothing shareable before)
        self._pending_insert: Dict[int, np.ndarray] = {}
        # partial-suffix preemption: rid -> (original prompt, tokens
        # generated before preemption).  The requeued request carries the
        # EFFECTIVE prompt (original + generated) so re-admission shares
        # the retained full pages and prefills only the tail; the saved
        # tokens are restored into the new SlotState so the result stream
        # is the full generation.  Entries are dropped on re-admission,
        # cancel, or fallback-to-recompute.
        self._resume: Dict[int, Tuple[np.ndarray, List[int]]] = {}
        # engine-set cap on the re-admission suffix (its static prefill
        # pad).  A resumed request whose retained pages were LRU-evicted
        # under pool pressure may face a suffix longer than the pad —
        # admit() then falls back to recomputing the original request.
        # None = no cap (chunked prefill streams any suffix).
        self.resume_pad: Optional[int] = None
        self.results: Dict[int, np.ndarray] = {}
        # rid -> {"reason", "stage", "tokens"} for aborted/timed-out
        # requests (they never appear in ``results``)
        self.cancelled: Dict[int, dict] = {}
        # (tick, slot, rid, chunk_tokens) per issued prefill chunk — the
        # per-tick-per-slot chunk-bound evidence the tests assert on
        self.prefill_log: List[Tuple[int, int, int, int]] = []
        # counters for the throughput bench / tests
        self.stats = {"admitted": 0, "completed": 0, "decode_steps": 0,
                      "slot_steps": 0, "active_slot_steps": 0,
                      "prefilled_tokens": 0, "prefix_tokens_skipped": 0,
                      "shared_pages": 0, "private_pages": 0,
                      "demand_pages": 0, "preemptions": 0, "cancelled": 0}

    # ---- submission / admission -----------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {len(req.prompt)} + max_new "
                f"{req.max_new} exceeds max_len {self.max_len}")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        self.queue.append(req)
        # the request span opens at queue entry (ts = arrival tick) and
        # closes exactly once, in commit() or _record_cancel() — preemption
        # requeues WITHOUT reopening, so span balance mirrors lifecycle
        # conservation (submitted == completed + cancelled at drain)
        self.tracer.begin(f"req:{req.rid}", "request", ts=req.arrival,
                          plen=len(req.prompt), max_new=req.max_new)

    def _alloc_or_evict(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages; on exhaustion, evict LRU prefix-cache
        pages first (pool pressure beats cache warmth), then retry."""
        pages = self.pool.alloc(n)
        if pages is None and self.prefix_cache is not None:
            self.prefix_cache.evict(n - self.pool.free_pages)
            pages = self.pool.alloc(n)
        return pages

    def _admission_plan(self, req: Request
                        ) -> Tuple[int, int, List[int], int]:
        """Prompt length, pages needed, shared prefix pages, and shared
        token count for admitting ``req`` (no allocation, no refs)."""
        plen = len(req.prompt)
        prompt_pages = min(max(1, -(-plen // self.page_size)),
                           self.n_pages_slot)
        shared: List[int] = []
        if self.prefix_cache is not None and plen > 1:
            shared = self.prefix_cache.match(req.prompt)
            shared = shared[:(plen - 1) // self.page_size]
        return plen, prompt_pages, shared, len(shared) * self.page_size

    def admit(self, tick: int) -> List[Tuple[int, Request, np.ndarray, int]]:
        """Place queued requests (arrival <= tick) into free slots while
        the pool can cover their prompts.  FIFO head-of-line: the queue is
        not reordered around a request that doesn't fit yet.

        With the prefix cache on, the longest cached full-page prefix of
        the prompt is SHARED: the slot's page-table row points at the
        cached physical pages (one pool reference each) and only the
        suffix needs private pages + prefill.  The match is capped at
        ``plen - 1`` tokens so the suffix is never empty (the engine
        still needs the last prompt token's logits to sample from); the
        partial tail page is always recomputed into a private page.
        """
        self.tracer.set_time(tick)
        placed = []
        for slot in range(self.n_slots):
            if not self.queue or self.slots[slot] is not None:
                continue
            req = self.queue[0]
            if req.arrival > tick:
                break
            resume = self._resume.get(req.rid)
            # demand-driven: only the PROMPT's pages at admission; decode
            # pages come from ensure_capacity tick by tick
            plen, prompt_pages, shared, pfx = self._admission_plan(req)
            if (resume is not None and self.resume_pad is not None
                    and plen - pfx > self.resume_pad):
                # pool pressure evicted the retained pages since the
                # preemption: the unshared suffix no longer fits the
                # engine's static prefill pad — fall back to a full
                # recompute of the ORIGINAL request (per-request greedy
                # streams regenerate the identical tokens)
                self._resume.pop(req.rid)
                req = dataclasses.replace(req, prompt=resume[0])
                self.queue[0] = req
                resume = None
                plen, prompt_pages, shared, pfx = self._admission_plan(req)
            # pin the matched pages BEFORE allocating: at refcount 1 the
            # eviction inside _alloc_or_evict could reclaim them and hand
            # them straight back as this request's private pages (one
            # physical page aliased as both prefix and suffix)
            for p in shared:
                self.pool.ref(p)
            priv = self._alloc_or_evict(prompt_pages - len(shared))
            if priv is None:
                # waiting is safe, not livelock: the pin cannot starve the
                # pool on its own (every non-pinned cache node is
                # evictable and usable pages >= n_pages_slot >=
                # prompt_pages), so failure means other ACTIVE slots hold
                # the pages — and they always finish
                self.pool.free(shared)          # unpin; retry next tick
                break
            self.queue.popleft()
            st = SlotState(req.rid, plen, req.max_new, written=plen,
                           prefill_pos=plen if self.prefill_chunk is None
                           else pfx)
            if resume is not None:
                # partial-suffix re-admission: the effective prompt ends
                # with the retained generation — restore it so commit()
                # budgets (max_new) and results cover the FULL stream.
                # The _resume entry stays (its [0] is the ORIGINAL prompt,
                # needed if this slot is preempted again); it is dropped
                # on completion or cancellation.
                st.tokens = list(resume[1])
            self.slots[slot] = st
            self._reqs[slot] = req
            self._adm_seq[slot] = self._seq
            self._seq += 1
            self._held[slot] = list(shared) + priv
            self._npages[slot] = prompt_pages
            row = np.full((self.n_pages_slot,), TRASH_PAGE, np.int32)
            row[:len(shared)] = shared
            row[len(shared):prompt_pages] = priv
            self._rows[slot] = row
            if self.prefix_cache is not None:
                self.prefix_cache.count(len(shared))
                if self.prefill_chunk is None:
                    # register this prompt's full pages for future
                    # admissions (contents land during this admission's
                    # prefill, before any later prefill could read them —
                    # admissions are prefilled in ``placed`` order)
                    self.prefix_cache.insert(req.prompt, row)
                else:
                    # chunked: pages fill over several ticks — insertion
                    # is deferred to the final chunk (``prefill_work``)
                    # so a later admission can never share unwritten pages
                    self._pending_insert[slot] = req.prompt
            self.stats["admitted"] += 1
            self.stats["prefilled_tokens"] += plen - pfx
            self.stats["prefix_tokens_skipped"] += pfx
            self.stats["shared_pages"] += len(shared)
            self.stats["private_pages"] += len(priv)
            trc = self.tracer
            trc.instant(f"req:{req.rid}", "admit", slot=slot, pfx=pfx)
            trc.counter("sched_admitted")
            if shared:
                trc.counter("pages_shared", len(shared))
            if priv:
                trc.counter("pages_private", len(priv))
            placed.append((slot, req, row.copy(), pfx))
        return placed

    # ---- chunked prefill --------------------------------------------------

    def prefill_work(self, tick: int
                     ) -> List[Tuple[int, Request, int, int, bool]]:
        """One prefill chunk per mid-prefill slot for this tick (chunked
        mode only).  Returns [(slot, request, start, clen, last)]: the
        engine writes prompt[start : start + clen] into the slot's pages
        (positions [start, start + clen)); ``last`` marks the final chunk
        (short, samples the first token via the suffix program).  At most
        ``prefill_chunk`` prompt tokens enter the cache per slot per tick
        — ``prefill_log`` records (tick, slot, rid, clen) as evidence."""
        if self.prefill_chunk is None:
            return []
        self.tracer.set_time(tick)
        out = []
        for slot in range(self.n_slots):
            st = self.slots[slot]
            if st is None or not st.prefilling:
                continue
            start = st.prefill_pos
            last = start + self.prefill_chunk >= st.plen
            clen = (st.plen - start) if last else self.prefill_chunk
            st.prefill_pos = start + clen
            if last and slot in self._pending_insert:
                # the slot's pages are fully written once the engine runs
                # this chunk (before any future admission could match)
                self.prefix_cache.insert(self._pending_insert.pop(slot),
                                         self._rows[slot])
            self.prefill_log.append((tick, slot, st.rid, clen))
            self.tracer.instant(f"req:{st.rid}", "prefill_chunk", slot=slot,
                                start=start, clen=clen, last=last)
            out.append((slot, self._reqs[slot], start, clen, last))
        return out

    # ---- demand-driven page growth / preemption --------------------------

    def _youngest_active(self) -> Optional[int]:
        live = [s for s, st in enumerate(self.slots) if st is not None]
        if not live:
            return None
        return max(live, key=lambda s: self._adm_seq[s])

    def _release_slot(self, slot: int) -> Request:
        """Return every page the slot holds to the pool and clear its
        state (complete/preempt/cancel all funnel through here — ONE
        place owns the page/slot conservation invariant)."""
        req = self._reqs.pop(slot)
        held = self._held.pop(slot)
        self.pool.free(held)
        self.tracer.counter("pages_released", len(held))
        self.slots[slot] = None
        self._rows.pop(slot)
        self._npages.pop(slot)
        self._adm_seq.pop(slot)
        self._pending_insert.pop(slot, None)
        return req

    def _preempt(self, slot: int) -> None:
        """Release ``slot`` and requeue its request at the FIFO head.

        With the prefix cache on, preemption is PARTIAL-SUFFIX: the
        slot's already-computed FULL pages (exactly the first ``written``
        rows — spec-mode rollback via ``PagedKVCache.truncate_to`` keeps
        device lengths == written) are adopted by the prefix cache
        before release, and the request requeues with the EFFECTIVE
        prompt (original + ALL committed tokens, length written + 1) so
        re-admission shares those pages and prefills only the tail.
        The resumed stream is bit-identical to an uninterrupted run:
        suffix prefill is quantize-then-attend through the same pages,
        and the continuation logits come from the same cache state.

        Without the prefix cache (or for a mid-prefill victim) this is
        recompute-style preemption: generated tokens are discarded and
        per-request greedy/sampling streams regenerate the identical
        stream on re-admission."""
        st = self.slots[slot]
        keep = (self.prefix_cache is not None and not st.prefilling
                and st.written >= self.page_size)
        # the ORIGINAL prompt: a once-resumed slot's live Request already
        # carries an effective prompt, so orig + st.tokens (tokens since
        # the FIRST admission) is the invariant reconstruction — its
        # length equals ``written + 1`` at every preemption depth (the
        # last committed token's row is always one tick from landing)
        rid = self._reqs[slot].rid
        orig = (self._resume[rid][0] if rid in self._resume
                else self._reqs[slot].prompt)
        if keep:
            # the effective prompt is orig + ALL committed tokens — the
            # LAST committed token's cache row is not written yet (it is
            # the next tick's input), so the page-cache insert is capped
            # at ``written`` rows while the requeued prompt keeps the
            # full stream (suffix prefill rewrites that one row and
            # samples the continuation, bit-identically)
            seq = np.concatenate([np.asarray(orig, np.int32),
                                  np.asarray(st.tokens, np.int32)])
            # adopt the full written pages BEFORE release: insert refs
            # them, so _release_slot's free leaves them alive in the tree
            self.prefix_cache.insert(seq[:st.written], self._rows[slot])
        req = self._release_slot(slot)
        if keep:
            self._resume[rid] = (orig, list(st.tokens))
            req = dataclasses.replace(req, prompt=seq)
        self.queue.appendleft(req)
        self.stats["preemptions"] += 1
        # the request span stays OPEN across preemption (it is still live,
        # just requeued); the instant marks the eviction point
        self.tracer.instant(f"req:{rid}", "preempt", slot=slot, keep=keep)
        self.tracer.counter("sched_preempted")

    def ensure_capacity(self, steps: int, advance: bool = True
                        ) -> Tuple[List[Tuple[int, np.ndarray]], List[int]]:
        """Grow every active slot's page row to cover this tick's
        ``steps`` decode writes.  Returns (growth, preempted): ``growth``
        is [(slot, new_row)] page-table updates for the engine; pool
        exhaustion evicts prefix-cache pages first, then preempts the
        youngest active slot until the survivors fit (the oldest slot is
        never preempted, so the trace always progresses).

        ``advance=False`` (speculative mode): grow rows for the
        worst-case ``steps`` (= k) candidate writes but do NOT bump
        ``written`` — the engine reports each slot's ACCEPTED length
        after the verify via ``advance_written``, so the high-water mark
        tracks only rows that survive the rollback."""
        growth: List[Tuple[int, np.ndarray]] = []
        preempted: List[int] = []
        if steps > 0:
            for slot in range(self.n_slots):
                while self.slots[slot] is not None:
                    st = self.slots[slot]
                    if st.prefilling:
                        # mid-chunked-prefill: no decode write this tick
                        # (masked in the decode program); prompt pages
                        # were fully allocated at admission
                        break
                    last = st.written + steps - 1       # last pos written
                    want = min(last // self.page_size + 1, self.n_pages_slot)
                    n_new = want - self._npages[slot]
                    if n_new <= 0:
                        break
                    pages = self._alloc_or_evict(n_new)
                    if pages is not None:
                        row = self._rows[slot]
                        row[self._npages[slot]:want] = pages
                        self._held[slot].extend(pages)
                        self._npages[slot] = want
                        self.stats["demand_pages"] += n_new
                        self.tracer.counter("pages_demand", n_new)
                        growth.append((slot, row.copy()))
                        break
                    victim = self._youngest_active()
                    if victim is None or victim == slot == \
                            self._oldest_active():
                        raise RuntimeError(
                            "page pool too small for a single request "
                            "(ensure_capacity cannot free more pages)")
                    self._preempt(victim)
                    preempted.append(victim)
                    if victim == slot:
                        break
        if advance:
            for st in self.slots:
                if st is not None and not st.prefilling:
                    st.written += max(0, steps)
        return growth, preempted

    def advance_written(self, slot: int, n: int) -> None:
        """Speculative-mode bookkeeping: advance a slot's ``written``
        high-water mark by its ACCEPTED length for the tick (the engine
        rolled back the rejected candidate rows via ``truncate_to``, so
        device lengths == written stays the invariant).  Call before
        ``commit`` — commit may release the slot."""
        st = self.slots[slot]
        if st is not None and not st.prefilling:
            st.written += max(0, n)

    def _oldest_active(self) -> Optional[int]:
        live = [s for s, st in enumerate(self.slots) if st is not None]
        if not live:
            return None
        return min(live, key=lambda s: self._adm_seq[s])

    # ---- decode bookkeeping ----------------------------------------------

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def decoding_slots(self) -> List[int]:
        """Active slots that are past prefill — the slots that emit (and
        commit) tokens this tick.  Mid-chunked-prefill slots are active
        (they hold pages) but not decoding."""
        return [i for i, s in enumerate(self.slots)
                if s is not None and not s.prefilling]

    def tick_steps(self, chunk: int,
                   pending: Optional[Dict[int, int]] = None) -> int:
        """Decode steps this tick: bounded by the tightest remaining
        budget so no active slot ever writes past its logical capacity.
        ``pending``: per-slot tokens already emitted but not yet committed
        (the engine's prefill-sampled first tokens) — they count against
        the budget.  Mid-chunked-prefill slots emit nothing and do not
        constrain the tick."""
        pending = pending or {}
        rem = [s.remaining - pending.get(i, 0)
               for i, s in enumerate(self.slots)
               if s is not None and not s.prefilling]
        return min([chunk] + rem) if rem else 0

    def commit(self, slot: int, toks: np.ndarray, eos_id: int) -> None:
        """Feed one tick's emitted tokens for ``slot``; finishes the slot
        on EOS or exhausted budget (page references return to the pool —
        pages shared with the prefix cache or other slots stay alive)."""
        st = self.slots[slot]
        for t in toks:
            if st.done:
                break
            st.tokens.append(int(t))
            if int(t) == eos_id or len(st.tokens) >= st.max_new:
                st.done = True
        if st.done:
            self.results[st.rid] = np.asarray(st.tokens, np.int32)
            self._release_slot(slot)
            self._resume.pop(st.rid, None)
            self.stats["completed"] += 1
            self.tracer.end(f"req:{st.rid}", "request",
                            ntokens=len(st.tokens))
            self.tracer.counter("sched_completed")

    # ---- request lifecycle: abort / timeout ------------------------------

    @staticmethod
    def _due(req: Request, tick: int) -> Optional[str]:
        """Hard-cancel reason for ``req`` at ``tick``, or None.  Checked
        at the START of a tick, before admission or any prefill/decode
        work is issued for it."""
        if req.abort_at is not None and tick >= req.abort_at:
            return "abort"
        if req.timeout is not None and tick >= req.arrival + req.timeout:
            return "timeout"
        return None

    def _record_cancel(self, req: Request, reason: str, stage: str,
                       tokens: List[int]) -> None:
        self.cancelled[req.rid] = {"reason": reason, "stage": stage,
                                   "tokens": np.asarray(tokens, np.int32)}
        self.stats["cancelled"] += 1
        # every cancel path (client abort / timeout, queued or placed)
        # funnels through here — the single span-closing point for
        # requests that never complete
        self.tracer.end(f"req:{req.rid}", "request", reason=reason,
                        stage=stage)
        self.tracer.counter("sched_cancelled")

    def cancel(self, rid: int, reason: str = "abort") -> bool:
        """Cancel request ``rid`` wherever it lives — queued (including
        preempted-and-requeued), mid-chunked-prefill, or decoding.  Slot
        pages funnel through ``_release_slot`` so conservation holds at
        every stage.  Returns False if the rid is unknown/finished."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                del self.queue[i]
                resume = self._resume.pop(rid, None)
                self._record_cancel(req, reason, "queued",
                                    [] if resume is None else resume[1])
                return True
        for slot, st in enumerate(self.slots):
            if st is not None and st.rid == rid:
                stage = "prefill" if st.prefilling else "decode"
                req = self._release_slot(slot)
                self._resume.pop(rid, None)
                self._record_cancel(req, reason, stage, st.tokens)
                return True
        return False

    def expire(self, tick: int) -> List[Tuple[Optional[int], int, str, str]]:
        """Run all due aborts/timeouts for ``tick`` (call at tick start,
        before ``admit``).  Returns [(slot_or_None, rid, stage, reason)] —
        the engine uses the freed slots to reset its host-side state."""
        self.tracer.set_time(tick)
        out: List[Tuple[Optional[int], int, str, str]] = []
        for req in [r for r in self.queue
                    if self._due(r, tick) is not None]:
            reason = self._due(req, tick)
            self.queue.remove(req)
            resume = self._resume.pop(req.rid, None)
            self._record_cancel(req, reason, "queued",
                                [] if resume is None else resume[1])
            out.append((None, req.rid, "queued", reason))
        for slot in range(self.n_slots):
            st = self.slots[slot]
            if st is None:
                continue
            reason = self._due(self._reqs[slot], tick)
            if reason is None:
                continue
            stage = "prefill" if st.prefilling else "decode"
            req = self._release_slot(slot)
            self._resume.pop(req.rid, None)
            self._record_cancel(req, reason, stage, st.tokens)
            out.append((slot, req.rid, stage, reason))
        return out

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def count_tick(self, steps: int, n_active: Optional[int] = None) -> None:
        """``n_active``: slots that were active DURING the tick (the caller
        snapshots it before commits free finished slots)."""
        if n_active is None:
            n_active = len(self.active_slots())
        self.stats["decode_steps"] += steps
        self.stats["slot_steps"] += steps * self.n_slots
        self.stats["active_slot_steps"] += steps * n_active

    @property
    def slot_utilization(self) -> float:
        """Active-slot decode steps / total slot-steps spent."""
        tot = self.stats["slot_steps"]
        return self.stats["active_slot_steps"] / tot if tot else 0.0

    @property
    def prefix_hit_rate(self) -> float:
        """Admissions that reused at least one cached prefix page."""
        adm = self.stats["admitted"]
        pc = self.prefix_cache
        return (pc.stats["hits"] / adm) if (pc and adm) else 0.0
