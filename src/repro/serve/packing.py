"""Quantize-once packed NVFP4 weight preparation for serving.

The QAF phase keeps the forward pass in FP4 so the deployed model is
FP4-inference-compatible — yet a naive serving engine re-fake-quantizes the
full bf16 weights from HBM on every decoded token, paying bf16 weight
bandwidth for FP4 numerics.  ``pack_model_params`` converts every GEMM
weight of a model pytree into a ``PackedQuantizedTensor`` (uint8 nibble
codes + float8 block scales + pow2 tensor scale, ~0.56 bytes/param for
NVFP4): quantization happens ONCE at engine build / checkpoint export, and
the forward path (core/fqt.py ``_packed_forward``) consumes the packed
representation directly.

Correctness invariant: ``PackedQuantizedTensor.dequant`` reconstructs the
fake-quant grid values bit-exactly, and the per-slice tensor scale of
``pack_quantize(batch_dims=...)`` matches per-GEMM quantization under
lax.scan/vmap slicing — so packed serving is token-identical to the
fake-quant forward.  Leaves NOT packed here (router, norms, biases, embed,
gates) take the unchanged path; packing is purely a storage/bandwidth
optimization, never a numerics change.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np

from repro.core.quantize import (BlockQuantSpec, PackedQuantizedTensor,
                                 pack_quantize)
from repro.models.config import ModelConfig

# Leaf names consumed as the RHS of ``QCtx.dense`` (x @ w, contraction on
# axis -2) across the model zoo.  Everything else — routers and recurrence
# gates (dense_hp, precision-critical), embeddings (table lookups), norms,
# biases, smooth factors, convs — stays in bf16.
WEIGHT_KEYS = frozenset({
    # transformer / moe
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "w_in", "w_out",
    # mamba2 (hybrid)
    "in_proj", "out_proj",
    # xlstm (ssm)
    "w_q", "w_k", "w_v", "w_gates", "w_ff_gate", "w_ff_up", "w_ff_down",
})
HEAD_KEYS = frozenset({"lm_head"})


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def _packable(name: str, leaf, spec: BlockQuantSpec,
              quantize_lm_head: bool) -> bool:
    if name in HEAD_KEYS:
        if not quantize_lm_head:
            return False
    elif name not in WEIGHT_KEYS:
        return False
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if not _is_float_leaf(leaf):
        return False
    # mirror fqt._if_divisible: irregular contraction dims stay bf16
    return leaf.shape[-2] % spec.block == 0 and leaf.shape[-1] % 2 == 0


def _is_float_leaf(leaf) -> bool:
    return np.issubdtype(np.dtype(leaf.dtype), np.floating) or \
        str(leaf.dtype) == "bfloat16"


def pack_model_params(cfg: ModelConfig, params: Any,
                      spec: Optional[BlockQuantSpec],
                      mesh: Optional[Any] = None) -> Any:
    """Pack every GEMM weight of ``params`` with ``spec`` (fwd_w).

    Stacked layer/expert weights keep their leading axes as batch dims
    (per-slice tensor scales), so scan/vmap layer application sees exactly
    the per-matrix quantization of the fake-quant forward.  Returns a new
    pytree; with ``spec=None`` the tree is returned unchanged (no packing).

    With ``mesh`` (a ``jax.sharding.Mesh``) the result is additionally
    placed under that mesh: every packed leaf's nibble-code / block-scale /
    tensor-scale arrays get the congruent partition specs of
    ``distributed/sharding.spec_for_packed`` (scale specs derived from code
    specs, so they can never diverge), and unpacked leaves follow the
    standard parameter rules.  A 1-device mesh is an identity placement —
    the unsharded engine is the degenerate case of the same path.
    """
    packed = params
    if spec is not None:
        def pack(path, leaf):
            name = _leaf_name(path)
            if not _packable(name, leaf, spec, cfg.quantize_lm_head):
                return leaf
            return pack_quantize(leaf, spec, axis=-2,
                                 batch_dims=leaf.ndim - 2)

        packed = jax.tree_util.tree_map_with_path(pack, params)

    if mesh is not None:
        from repro.distributed.sharding import place_serve_params
        packed = place_serve_params(packed, mesh)
    return packed


def weight_store_bytes(params: Any) -> int:
    """Total stored bytes of a params pytree (packed leaves counted at their
    packed size) — the decode-path weight HBM traffic per full pass."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, PackedQuantizedTensor)):
        if isinstance(leaf, PackedQuantizedTensor):
            total += leaf.nbytes()
        elif hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
    return total


def weight_wire_bytes(params: Any) -> int:
    """Bytes a full FSDP-style weight all-gather moves under the serving
    mesh: packed leaves travel as their wire format (uint8 nibble codes +
    f8 block scales, ~4.5 bits/param — ``PackedQuantizedTensor.
    wire_nbytes``; the replicated tscale never travels), unpacked leaves
    as stored."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, PackedQuantizedTensor)):
        if isinstance(leaf, PackedQuantizedTensor):
            total += leaf.wire_nbytes()
        elif hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
    return total


def param_count(params: Any) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, PackedQuantizedTensor)):
        total += int(np.prod(leaf.shape)) if hasattr(leaf, "shape") else 0
    return total
