"""Seeded multi-tenant workload generator for the serving traffic harness.

Production serving systems are judged on TTFT/TPOT/goodput under
realistic multi-tenant load, not on one FIFO trace.  This module builds
such load DETERMINISTICALLY: every arrival tick, prompt token, abort and
deadline derives from a single integer seed through counter-based
``numpy`` PCG64 streams — the same ``WorkloadConfig`` always produces the
same trace byte-for-byte, on any machine, with no wall-clock anywhere
(the simulated clock is the scheduler tick).

Per tenant (``TenantSpec``):

  * a Poisson arrival process (``rate`` mean arrivals per tick), plus an
    optional deterministic BURST overlay (``burst_every``/``burst_size``)
    modelling batch jobs behind an interactive tenant;
  * a prompt-length mixture (``prompt_lens``/``prompt_probs``) and a
    shared SYSTEM PROMPT (``system_prompt_len`` tokens, identical for
    every request of the tenant) — the prefix-cache workload shape;
  * SLO/lifecycle knobs: ``deadline_slack`` (soft deadline, goodput
    only), ``abort_prob``/``abort_after`` (hard client aborts) and
    ``timeout`` (hard cancel relative to arrival) — all mapped onto
    ``scheduler.Request`` fields.

Each tenant draws from its OWN child stream (``SeedSequence([seed, t])``)
so adding a tenant never perturbs another tenant's trace.  Request ids
are assigned sequentially in (arrival, tenant, intra-tick) order — the
admission order of a FIFO replay.

Host-side and numpy-only, like ``serve/metrics.py`` — the generator and
its determinism check run from ``tools/check_env.py --traffic`` without
touching the accelerator stack.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic model.  All times are scheduler ticks."""
    name: str
    rate: float = 0.5                    # mean Poisson arrivals per tick
    prompt_lens: Tuple[int, ...] = (8, 16)       # mixture support (tokens
                                                 # EXCLUDING system prompt)
    prompt_probs: Optional[Tuple[float, ...]] = None   # None = uniform
    system_prompt_len: int = 0           # shared prefix, same tokens for
                                         # every request of this tenant
    max_new: int = 16
    deadline_slack: Optional[int] = None  # deadline = arrival + slack
    abort_prob: float = 0.0              # chance a request hard-aborts
    abort_after: int = 4                 # abort_at = arrival + abort_after
    timeout: Optional[int] = None        # hard cancel, relative to arrival
    burst_every: Optional[int] = None    # every k ticks, extra arrivals
    burst_size: int = 0

    def __post_init__(self):
        if self.rate < 0:
            raise ValueError(f"tenant {self.name}: rate must be >= 0")
        if not self.prompt_lens:
            raise ValueError(f"tenant {self.name}: empty prompt_lens")
        if self.prompt_probs is not None and \
                len(self.prompt_probs) != len(self.prompt_lens):
            raise ValueError(
                f"tenant {self.name}: prompt_probs length "
                f"{len(self.prompt_probs)} != prompt_lens length "
                f"{len(self.prompt_lens)}")
        if not (0.0 <= self.abort_prob <= 1.0):
            raise ValueError(f"tenant {self.name}: abort_prob must be a "
                             f"probability")


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """A full multi-tenant trace: ``tenants`` over ``ticks`` simulated
    ticks, every random draw derived from ``seed``."""
    tenants: Tuple[TenantSpec, ...]
    ticks: int = 32
    seed: int = 0
    vocab: int = 256                     # token id range for synthetic
                                         # prompts (kept below real vocabs)

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("workload needs at least one tenant")
        if self.ticks < 1:
            raise ValueError("workload needs >= 1 tick")


@dataclasses.dataclass(frozen=True)
class WorkloadEvent:
    """One generated request, engine-agnostic (plain numpy).  Field names
    mirror ``scheduler.Request`` so ``as_requests`` is a 1:1 mapping."""
    rid: int
    tenant: str
    prompt: np.ndarray
    max_new: int
    arrival: int
    deadline: Optional[int] = None
    abort_at: Optional[int] = None
    timeout: Optional[int] = None


def _tenant_stream(seed: int, tenant_idx: int) -> np.random.Generator:
    """Counter-based child stream: tenant ``tenant_idx`` of workload
    ``seed``.  Independent of tenant iteration order and of every other
    tenant's draw count."""
    return np.random.Generator(
        np.random.PCG64(np.random.SeedSequence([seed, tenant_idx])))


def generate_workload(wcfg: WorkloadConfig) -> List[WorkloadEvent]:
    """The full trace for ``wcfg``, sorted by (arrival, tenant index,
    intra-tick order) with sequential rids in that order."""
    raw: List[Tuple[int, int, int, WorkloadEvent]] = []
    for ti, spec in enumerate(wcfg.tenants):
        g = _tenant_stream(wcfg.seed, ti)
        system = g.integers(0, wcfg.vocab,
                            size=spec.system_prompt_len).astype(np.int32)
        probs = spec.prompt_probs
        lens = np.asarray(spec.prompt_lens)
        for t in range(wcfg.ticks):
            n = int(g.poisson(spec.rate))
            if spec.burst_every and t % spec.burst_every == 0:
                n += spec.burst_size
            for k in range(n):
                plen = int(g.choice(lens, p=probs))
                body = g.integers(0, wcfg.vocab, size=plen).astype(np.int32)
                abort_at = None
                if spec.abort_prob > 0 and g.random() < spec.abort_prob:
                    abort_at = t + spec.abort_after
                ev = WorkloadEvent(
                    rid=-1, tenant=spec.name,
                    prompt=np.concatenate([system, body]),
                    max_new=spec.max_new, arrival=t,
                    deadline=(t + spec.deadline_slack
                              if spec.deadline_slack is not None else None),
                    abort_at=abort_at, timeout=spec.timeout)
                raw.append((t, ti, k, ev))
    raw.sort(key=lambda r: r[:3])
    return [dataclasses.replace(ev, rid=i)
            for i, (_, _, _, ev) in enumerate(raw)]


def as_requests(events: List[WorkloadEvent]) -> list:
    """Map a trace onto ``scheduler.Request`` objects (imported lazily:
    the generator itself stays importable without the serve engine)."""
    from repro.serve.scheduler import Request
    return [Request(rid=e.rid, prompt=e.prompt, max_new=e.max_new,
                    arrival=e.arrival, deadline=e.deadline,
                    abort_at=e.abort_at, timeout=e.timeout)
            for e in events]


def trace_fingerprint(events: List[WorkloadEvent]) -> bytes:
    """Byte-exact digest of a trace — two generator runs agree iff their
    fingerprints agree (the determinism check in ``check_env --traffic``
    and tests/test_workload.py)."""
    parts = []
    for e in events:
        head = (f"{e.rid}|{e.tenant}|{e.max_new}|{e.arrival}|{e.deadline}"
                f"|{e.abort_at}|{e.timeout}|").encode()
        parts.append(head + np.asarray(e.prompt, np.int32).tobytes())
    return b"\x00".join(parts)
