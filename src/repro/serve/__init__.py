from repro.serve.engine import Engine, ServeConfig, serve_step_fn
