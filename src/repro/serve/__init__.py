from repro.serve import packing
from repro.serve.engine import (ContinuousEngine, Engine, ServeConfig,
                                serve_step_fn)
from repro.serve.metrics import MetricsRecorder, percentile
from repro.serve.packing import pack_model_params, weight_store_bytes
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import PagePool, Request, Scheduler
from repro.serve.workload import (TenantSpec, WorkloadConfig,
                                  WorkloadEvent, as_requests,
                                  generate_workload, trace_fingerprint)
