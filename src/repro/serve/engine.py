"""Serving engines: FP4 forward, prefill + decode with KV caches.

The deployed artifact of the paper's pipeline is an *FP4-forward* model (the
QAF phase keeps the forward path in FP4 precisely so the served model is
FP4-inference-compatible).  Both engines run every weight GEMM through the
same NVFP4 RtN forward quantization used in training — serving is
numerically identical to the training forward pass.

Two engines share the packed-weight/packed-cache machinery:

  * ``Engine`` — LOCKSTEP batches: all requests prefill together and the
    batch decodes until every sequence finishes.  Simple, and the numeric
    reference for the continuous engine.
  * ``ContinuousEngine`` — vLLM-style CONTINUOUS batching over a paged
    NVFP4 KV cache.  Request lifecycle (admission queue, per-slot lengths,
    slot free/reuse on EOS/max_len, demand-driven paging + preemption,
    abort/timeout cancellation, the exact shared-prefix cache) lives in
    ``serve/scheduler.py`` on the host; the device side is EXACTLY FIVE
    jitted programs with static shapes —

        prefill-into-slot : right-padded (1, prefill_len) prompt into one
                            slot's pages (dynamic slot/plen operands)
        prefill-suffix    : warm shared-prefix admission — only the
                            prompt SUFFIX (dynamic pfx/plen/slot), the
                            prefix pages are shared from the prefix cache
        prefill-chunk     : one FULL intermediate chunk of a long prompt
                            (chunked prefill; dynamic slot/offset
                            operands, no sampling — the final short
                            chunk reuses prefill-suffix)
        batched decode    : one token for every slot, per-slot
                            kv_len/q_offset VECTOR operands + an active
                            mask freezing mid-prefill slots
        verify-k          : speculative decoding (``spec_k``) — the
                            layer-truncated self-draft proposes k-1
                            tokens per slot, one teacher-forced pass
                            verifies the block bit-exactly, rejected
                            cache rows roll back (truncate_to); static
                            (slots, k) shapes, accepted length is a
                            dynamic OUTPUT

    so admitting a queued request into a freed slot never recompiles.
    Host sync happens once per scheduler TICK (``decode_chunk`` steps),
    not per token.

  * quantize-once packed weights: GEMM weights are packed to NVFP4 storage
    (uint8 nibble codes + float8 block scales, ~0.56 bytes/param) at
    engine build — bit-identical tokens (serve/packing.py).
  * block-quantized KV cache (``ServeConfig.kv_cache_format``): "nvfp4"
    (default, 0.5625 bytes/elem), "fp8", or the "bf16" escape hatch; the
    continuous engine stores the same formats per PAGE
    (models/layers.PagedKVCache).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import fqt
from repro.distributed import sharding as shd
from repro.models import registry
from repro.models.config import ModelConfig
from repro.models.layers import TRASH_PAGE, PagedKVCache
from repro.obs.trace import NULL_TRACER
from repro.serve import packing
from repro.serve.metrics import MetricsRecorder
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_size: int = 8
    max_len: int = 2048
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => no top-k filtering
    eos_id: int = 2
    seed: int = 0
    # KV cache storage: "nvfp4" (E2M1 nibble codes + f8 block scales along
    # the head dim, 0.5625 bytes/elem, ~3.56x less decode-attention HBM
    # traffic), "fp8" (f8 codes + bf16 block scales, 1.125 bytes/elem), or
    # "bf16" — the unquantized escape hatch.  Cache writes are quantized
    # with RtN (the paper's inference forward rounding); decode attention
    # dequantizes K/V blocks on the fly, never materializing a bf16 cache.
    kv_cache_format: str = "nvfp4"
    # ---- continuous batching (ContinuousEngine) -------------------------
    page_size: int = 16           # tokens per KV page
    max_slots: Optional[int] = None    # decode slots (default: batch_size)
    total_pages: Optional[int] = None  # page-pool size (None: one full
                                       # reservation per slot + trash page)
    prefill_len: Optional[int] = None  # static prefill pad (None: derived
                                       # from the submitted trace)
    decode_chunk: int = 8         # decode steps per scheduler tick — the
                                  # host-sync cadence for BOTH engines
    # chunked prefill: a prompt enters its slot ``prefill_chunk`` tokens
    # per scheduler TICK (a fourth jitted program with dynamic offset
    # operands), interleaved with decode — one long prompt never stalls a
    # decode tick by more than one chunk.  Each chunk attends THROUGH the
    # quantized paged cache (the prefix-cache suffix machinery), so
    # chunking is bit-exact for every kv_cache_format.  Dense/moe,
    # linear (non-SWA) caches only.  None = full prefill at admission.
    prefill_chunk: Optional[int] = None
    # exact shared-prefix cache (serve/prefix_cache.py): admissions whose
    # prompt shares cached full pages point their page-table rows at the
    # shared physical pages and prefill only the suffix.  Dense/moe,
    # linear (non-SWA) caches only.
    prefix_cache: bool = False
    prefix_cache_pages: Optional[int] = None   # cap on cached pages (LRU)
    # ---- speculative decoding (ContinuousEngine) ------------------------
    # self-draft verify-k: every decode tick, a draft model made of the
    # FIRST ``draft_layers`` layers of the SAME packed weights (a trace-
    # level slice of the stacked layer axis — zero extra HBM for weights)
    # proposes spec_k - 1 greedy tokens per slot from a sliced, discarded
    # copy of the paged caches; ONE batched teacher-forced verify pass
    # through the quantized paged cache then accepts the longest matching
    # greedy prefix plus one corrected token (1..spec_k tokens per slot
    # per tick) and rolls the rejected rows back exactly
    # (PagedKVCache.truncate_to).  Greedy verification is provably
    # output-identical: speculative streams are BIT-identical to the
    # non-speculative engine for every kv_cache_format.  Greedy only
    # (temperature == 0), dense/moe families, linear (non-SWA) caches.
    spec_k: Optional[int] = None        # verify block size (None = off)
    draft_layers: Optional[int] = None  # draft depth (None with spec_k on:
                                        # n_layers // 2)
    # ---- mesh-native serving --------------------------------------------
    # "--mesh" spec ("tp=2", "dp=2,tp=4", ...) for the explicit serving
    # Mesh BOTH engines place their weights and KV pools under.  None means
    # the degenerate 1-device mesh — the SAME code path (placement under a
    # 1-device mesh is the identity), never an ``if sharded:`` fork.  TP
    # shards heads/hidden/vocab on "model" (Megatron column/row-parallel
    # packed GEMMs via GSPMD); KV page pools shard their KV-heads axis.
    mesh: Optional[str] = None


def _sample(logits: jax.Array, key, scfg: ServeConfig) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if scfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / scfg.temperature
    if scfg.top_k > 0:
        kth = jax.lax.top_k(logits, scfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def _greedy_margin(logits: jax.Array) -> jax.Array:
    """Top1-top2 logit gap per row — how decisive the greedy pick is.
    Near-tied rows are where bounded numeric perturbations flip greedy
    tokens (random-init smoke models have near-flat logits); the engine
    tests gate token-identity assertions on this margin."""
    top2 = jax.lax.top_k(logits.astype(jnp.float32), 2)[0]
    return top2[..., 0] - top2[..., 1]


class Engine:
    """Single-model LOCKSTEP serving engine over the uniform registry API."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 qcfg: Optional[fqt.QuantConfig] = None,
                 pack_weights: bool = True,
                 mesh: Optional[Mesh] = None):
        self.cfg, self.scfg = cfg, scfg
        # serving default: the paper's FP4 forward (RtN), nothing else
        self.qcfg = qcfg if qcfg is not None else fqt.qaf_config()
        # ONE mesh-native path: scfg.mesh == None resolves to the 1-device
        # mesh, whose placement is the identity — no ``if sharded:`` fork.
        self.mesh = mesh if mesh is not None \
            else shd.make_serve_mesh(scfg.mesh)
        self._rep = NamedSharding(self.mesh, P())
        # quantize ONCE: every GEMM weight becomes packed NVFP4 storage;
        # the forward consumes it directly (fqt._packed_forward), token-
        # identical to re-fake-quantizing per GEMM.  Packed or not, the
        # tree is placed under the serving mesh (congruent code/scale
        # specs for packed leaves, rank+name rules otherwise).
        spec = self.qcfg.fwd_w \
            if (pack_weights and self.qcfg.fwd_w is not None) else None
        self.params = packing.pack_model_params(cfg, params, spec,
                                                mesh=self.mesh)

        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl, donate_argnums=(2,))

    def _replicate(self, *xs):
        """Pin small host-facing arrays (tokens/masks/keys) replicated on
        the serving mesh, so every jit call sees the SAME input shardings
        (no-recompile guarantee) and GSPMD never scatters token vectors."""
        out = tuple(jax.device_put(x, self._rep) for x in xs)
        return out if len(out) > 1 else out[0]

    # ---- compiled kernels --------------------------------------------------

    def _prefill_impl(self, tokens, carry, extras):
        logits, carry = registry.prefill(self.params, self.cfg, self.qcfg,
                                         tokens, carry, extras=extras)
        return logits, shd.constrain_serve_cache(carry, self.mesh)

    def _decode_impl(self, tokens, done, carry, key):
        """One lockstep decode step with ON-DEVICE done/EOS bookkeeping:
        emit = the masked output token for this step, done accumulates the
        EOS mask, and the PRNG chain advances on device — the host only
        syncs once per ``decode_chunk`` tick."""
        eos = jnp.int32(self.scfg.eos_id)
        emit = jnp.where(done, eos, tokens)
        done = done | (tokens == eos)
        key, sub = jax.random.split(key)
        logits, carry = registry.decode_step(self.params, self.cfg,
                                             self.qcfg, emit[:, None],
                                             carry)
        nxt = _sample(logits[:, -1], sub, self.scfg)
        # pin the small host-facing outputs replicated: the NEXT call's
        # input shardings equal this call's (one compile per program, on
        # any mesh); purely a layout annotation after sampling — the
        # GEMM/attention numerics upstream are untouched.
        emit, done, nxt, key = (
            jax.lax.with_sharding_constraint(x, self._rep)
            for x in (emit, done, nxt, key))
        return emit, done, nxt, shd.constrain_serve_cache(carry,
                                                          self.mesh), key

    # ---- public API ----------------------------------------------------------

    def generate(self, prompts: List[np.ndarray], max_new: int = 32,
                 extras: Optional[dict] = None) -> List[np.ndarray]:
        """Greedy/temperature generation for a batch of token prompts."""
        scfg, cfg = self.scfg, self.cfg
        B = len(prompts)
        if B > scfg.batch_size:
            raise ValueError(f"{B} prompts > batch_size {scfg.batch_size}")
        # pad the batch to the fixed slot count
        plen = max(len(p) for p in prompts)
        toks = np.zeros((scfg.batch_size, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p       # left-pad (simplest static shape)
        toks = jnp.asarray(toks)

        carry = registry.make_decode_state(
            cfg, scfg.batch_size, scfg.max_len,
            kv_cache_format=scfg.kv_cache_format)
        carry = shd.place_serve_cache(carry, self.mesh)
        toks = self._replicate(toks)
        extras = extras or {}
        last_logits, carry = self._prefill(toks, carry, extras)

        # PRNG hygiene: split the root key FIRST — the first sampled token
        # uses a child, never the parent of the per-step chain.
        key, sub = jax.random.split(jax.random.PRNGKey(scfg.seed))
        nxt = _sample(last_logits, sub, scfg)
        key, nxt = self._replicate(key, nxt)
        done = self._replicate(jnp.zeros((scfg.batch_size,), bool))
        emitted = []                      # device arrays; no per-step sync
        sync = max(1, scfg.decode_chunk)
        for t in range(max_new):
            emit, done, nxt, carry, key = self._decode(nxt, done, carry, key)
            emitted.append(emit)
            # transfer the done mask once per tick, not per token
            if (t + 1) % sync == 0 and bool(np.asarray(done).all()):
                break
        if not emitted:                   # max_new == 0
            return [np.zeros((0,), np.int32) for _ in range(B)]
        out = np.asarray(jnp.stack(emitted, axis=1))     # one transfer
        # truncate at the first step where every row had emitted its EOS
        seen = np.cumsum(out == scfg.eos_id, axis=1) > 0
        alldone = seen.all(axis=0)
        if alldone.any():
            out = out[:, : int(np.argmax(alldone)) + 1]
        return [out[i] for i in range(B)]


class ContinuousEngine:
    """Continuous batching over a paged, block-quantized KV cache.

    Requests arrive on a (deterministic, tick-indexed) trace, wait in the
    scheduler's FIFO queue, and are admitted whenever a slot AND enough
    free pages exist; slots free on EOS/max_new and are reused without
    recompilation.  Families: dense/moe transformers and the whisper
    decoder (``encdec``).  The recurrent families absorb pad tokens into
    O(1) state, so a static right-padded prefill can't serve them — they
    stay on the lockstep ``Engine`` (registry.prefill_slot raises).
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 qcfg: Optional[fqt.QuantConfig] = None,
                 pack_weights: bool = True,
                 mesh: Optional[Mesh] = None,
                 tracer=None):
        if cfg.family not in ("dense", "moe", "encdec"):
            raise NotImplementedError(
                f"continuous batching serves dense/moe/encdec families; "
                f"{cfg.family!r} stays on the lockstep Engine")
        # host-side trace emission only (obs/trace.py): spans per tick,
        # instants per jit compile — NEVER inside the jitted bodies below
        # (fp4lint's obs-in-jit rule enforces this), so an attached tracer
        # cannot perturb tokens or compile counts
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.cfg, self.scfg = cfg, scfg
        self.qcfg = qcfg if qcfg is not None else fqt.qaf_config()
        # same mesh-native path as the lockstep Engine (1-device default)
        self.mesh = mesh if mesh is not None \
            else shd.make_serve_mesh(scfg.mesh)
        self._rep = NamedSharding(self.mesh, P())
        spec = self.qcfg.fwd_w \
            if (pack_weights and self.qcfg.fwd_w is not None) else None
        self.params = packing.pack_model_params(cfg, params, spec,
                                                mesh=self.mesh)

        self.n_slots = scfg.max_slots or scfg.batch_size
        psz = scfg.page_size
        buf = (scfg.max_len if cfg.sliding_window is None
               else min(scfg.max_len, cfg.sliding_window))
        self.slot_buf = -(-buf // psz) * psz   # logical tokens per slot
        self.n_pages_slot = self.slot_buf // psz
        if scfg.prefix_cache and (cfg.family not in ("dense", "moe")
                                  or cfg.sliding_window is not None):
            raise NotImplementedError(
                "prefix_cache needs prompt-pure K/V and a linear cache: "
                "dense/moe families without a sliding window")
        if scfg.prefill_chunk is not None:
            if cfg.family not in ("dense", "moe") or \
                    cfg.sliding_window is not None:
                raise NotImplementedError(
                    "prefill_chunk needs prompt-pure K/V and a linear "
                    "cache: dense/moe families without a sliding window "
                    "(chunks attend THROUGH the quantized paged cache)")
            if not 1 <= scfg.prefill_chunk <= self.slot_buf:
                raise ValueError(
                    f"prefill_chunk {scfg.prefill_chunk} out of range "
                    f"[1, {self.slot_buf}]")
        self.spec = scfg.spec_k is not None
        self.draft_layers = 0
        if scfg.draft_layers is not None and not self.spec:
            raise ValueError("draft_layers requires spec_k (speculative "
                             "decoding off)")
        if self.spec:
            if cfg.family not in ("dense", "moe"):
                raise NotImplementedError(
                    "speculative decoding needs an exactly rewindable "
                    "paged cache: dense/moe families only")
            if cfg.sliding_window is not None:
                raise NotImplementedError(
                    "speculative decoding needs a linear cache; SWA "
                    "rolling buffers cannot roll back exactly")
            if scfg.temperature > 0.0:
                raise NotImplementedError(
                    "speculative verify is greedy-only (temperature 0): "
                    "the acceptance rule is exact argmax agreement")
            if scfg.spec_k < 2:
                raise ValueError(f"spec_k must be >= 2, got {scfg.spec_k}")
            dl = (scfg.draft_layers if scfg.draft_layers is not None
                  else max(1, cfg.n_layers // 2))
            if not 1 <= dl <= cfg.n_layers:
                raise ValueError(
                    f"draft_layers {dl} out of range [1, {cfg.n_layers}]")
            self.draft_layers = dl
            self._draft_cfg = dataclasses.replace(cfg, n_layers=dl)
        self._root = jax.random.PRNGKey(scfg.seed)

        self._prefill = jax.jit(self._prefill_impl, donate_argnums=(4,))
        self._prefill_sfx = jax.jit(self._prefill_suffix_impl,
                                    donate_argnums=(5,))
        self._prefill_chk = jax.jit(self._prefill_chunk_impl,
                                    donate_argnums=(3,))
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._verify = jax.jit(self._verify_k_impl, donate_argnums=(1,))

    def _replicate(self, *xs):
        """See ``Engine._replicate`` — stable input shardings under the
        mesh for the host-facing token/step vectors."""
        out = tuple(jax.device_put(x, self._rep) for x in xs)
        return out if len(out) > 1 else out[0]

    def _pin(self, *xs):
        """In-jit counterpart of ``_replicate``: annotate already-computed
        outputs replicated so the next call's input shardings match this
        call's (the three-program / no-recompile guarantee holds on any
        mesh).  Applied after sampling — upstream numerics untouched."""
        out = tuple(jax.lax.with_sharding_constraint(x, self._rep)
                    for x in xs)
        return out if len(out) > 1 else out[0]

    # ---- the two compiled programs ----------------------------------------

    def _request_key(self, rid, step):
        """Per-request sampling stream, keyed by REQUEST ID (not slot), so
        slot reuse never replays another request's stream."""
        return jax.random.fold_in(jax.random.fold_in(self._root, rid), step)

    def _prefill_impl(self, tokens, plen, slot, rid, carry, extras):
        """Prefill one slot from a right-padded (1, prefill_len) prompt and
        sample that request's first token.  slot/plen/rid are DYNAMIC
        operands — one compiled program serves every admission."""
        logits, carry = registry.prefill_slot(
            self.params, self.cfg, self.qcfg, tokens, carry, slot, plen,
            extras=extras)
        tok = _sample(logits, self._request_key(rid, 0), self.scfg)[0]
        tok, margin = self._pin(tok, _greedy_margin(logits)[0])
        return tok, margin, shd.constrain_serve_cache(carry, self.mesh)

    def _prefill_suffix_impl(self, tokens, plen, pfx, slot, rid, carry):
        """Warm-prefix prefill: the slot's page row already shares the
        cached prefix pages; write + attend only the SUFFIX of the prompt
        (right-padded (1, prefill_len), dynamic pfx/plen/slot/rid
        operands — one compiled program serves every warm admission)."""
        logits, carry = registry.prefill_suffix(
            self.params, self.cfg, self.qcfg, tokens, carry, slot, plen,
            pfx)
        tok = _sample(logits, self._request_key(rid, 0), self.scfg)[0]
        tok, margin = self._pin(tok, _greedy_margin(logits)[0])
        return tok, margin, shd.constrain_serve_cache(carry, self.mesh)

    def _prefill_chunk_impl(self, tokens, slot, off, carry):
        """Chunked prefill, intermediate chunk: write one FULL
        (1, prefill_chunk) slice of a long prompt into a slot's pages at
        positions [off, off + C) — the fourth jitted program (dynamic
        slot/off operands; no logits, no sampling — the final, possibly
        short chunk reuses the suffix program and samples there)."""
        carry = registry.prefill_chunk(self.params, self.cfg, self.qcfg,
                                       tokens, carry, slot, off)
        return shd.constrain_serve_cache(carry, self.mesh)

    def _decode_impl(self, tokens, carry, rids, steps, active):
        """One token for every slot; per-slot kv_len/q_offset ride inside
        the paged caches (``PagedKVCache.lengths``) as vector state.

        ``active`` ((n_slots,) bool): in chunked-prefill mode, slots that
        are NOT decoding this tick (mid-prefill or empty) write to the
        trash page with frozen lengths, so a decode tick can never
        corrupt a partially-prefilled slot's pages.  Without chunked
        prefill the operand is dropped at trace time (write_mask=None),
        keeping the non-chunked program byte-identical to before."""
        mask = active if self.scfg.prefill_chunk is not None else None
        logits, carry = registry.decode_step(self.params, self.cfg,
                                             self.qcfg, tokens[:, None],
                                             carry, write_mask=mask)
        lg = logits[:, -1]
        if self.scfg.temperature <= 0.0:
            nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        else:
            keys = jax.vmap(self._request_key)(rids, steps)
            nxt = jax.vmap(
                lambda l, k: _sample(l[None], k, self.scfg)[0])(lg, keys)
        nxt, margin, steps = self._pin(nxt, _greedy_margin(lg), steps + 1)
        return nxt, margin, steps, shd.constrain_serve_cache(carry,
                                                             self.mesh)

    def _verify_k_impl(self, tokens, carry, rids, steps, active):
        """Fifth jitted program — speculative verify-k, static (slots, k)
        shapes with the accepted length as a dynamic OUTPUT, so one
        compile serves every tick whatever each slot accepts.

        Three phases, all inside one jit:
          1. DRAFT: the layer-truncated self-draft model (first
             ``draft_layers`` layers of the same packed weights) greedily
             proposes k-1 tokens per slot from a SLICED COPY of the paged
             caches.  The copy is discarded after drafting — functional
             purity means the real carry is never touched, so there is no
             draft state to merge or roll back, and the sliced layers'
             cache rows are exactly the draft model's own history (layer
             l < draft_layers of the target computes the identical
             rows).
          2. VERIFY: one teacher-forced pass of the block [t0, d1..dk-1]
             through the full model's paged quantized cache.  Causal
             masking + per-slot kv_len give query row j exactly the rows
             [0, len + j] sequential decode would see, so row j's greedy
             pick is BIT-identical to non-speculative decode.
          3. ACCEPT + ROLLBACK: the longest prefix of drafts matching the
             verify argmaxes plus one corrected token is emitted
             (n_emit in 1..k); ``truncate_to`` rewinds every layer's
             lengths over the rejected rows (the pool keeps their stale
             codes — reads mask by length, the next append overwrites).
        """
        scfg = self.scfg
        k = scfg.spec_k
        mask = active if scfg.prefill_chunk is not None else None
        dparams, dcarry = registry.draft_view(self.params, carry,
                                              self.draft_layers)
        blk = [tokens]
        tok = tokens
        for _ in range(k - 1):
            lg, dcarry = registry.decode_step(
                dparams, self._draft_cfg, self.qcfg, tok[:, None], dcarry,
                write_mask=mask)
            tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
            blk.append(tok)
        blk = jnp.stack(blk, axis=1)                         # (B, k)
        lg, carry = registry.verify_k(self.params, self.cfg, self.qcfg,
                                      blk, carry, write_mask=mask)
        g = jnp.argmax(lg, axis=-1).astype(jnp.int32)        # (B, k)
        margin = _greedy_margin(lg)                          # (B, k)
        match = (g[:, :k - 1] == blk[:, 1:]).astype(jnp.int32)
        acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)    # (B,) 0..k-1
        n_emit = acc + 1                                     # (B,) 1..k
        wrote = (jnp.ones_like(n_emit) if mask is None
                 else mask.astype(jnp.int32))
        if mask is not None:
            n_emit = n_emit * wrote              # masked slots emit nothing
        # exact rollback: post-write lengths are base + k*wrote; rewind
        # to base + n_emit (a pure lengths update, pool bytes untouched)
        delta = n_emit - jnp.int32(k) * wrote

        def rb(c):
            if isinstance(c, PagedKVCache):
                return c.truncate_to(None, c.lengths + delta)
            return c

        carry = jax.tree_util.tree_map(
            rb, carry, is_leaf=lambda x: isinstance(x, PagedKVCache))
        # next tick's t0: the LAST emitted token, g[slot, n_emit - 1]
        nxt = jnp.take_along_axis(
            g, jnp.maximum(n_emit - 1, 0)[:, None], axis=1)[:, 0]
        g, margin, n_emit, nxt, steps = self._pin(
            g, margin, n_emit, nxt, steps + n_emit)
        return g, margin, n_emit, nxt, steps, \
            shd.constrain_serve_cache(carry, self.mesh)

    # ---- jit-cache introspection (no-recompile guarantees) -----------------

    @property
    def prefill_compiles(self) -> int:
        return self._prefill._cache_size()

    @property
    def prefill_suffix_compiles(self) -> int:
        return self._prefill_sfx._cache_size()

    @property
    def chunk_compiles(self) -> int:
        return self._prefill_chk._cache_size()

    @property
    def decode_compiles(self) -> int:
        return self._decode._cache_size()

    @property
    def verify_compiles(self) -> int:
        return self._verify._cache_size()

    # ---- host-side plumbing ------------------------------------------------

    def _set_page_row(self, carry, slot: int, row: np.ndarray):
        """Point one slot's page-table row (all layers) at new pages —
        the only carry mutation done outside the two compiled programs
        (a few hundred int32s per admission)."""
        row = jnp.asarray(row, jnp.int32)

        def upd(c):
            if isinstance(c, PagedKVCache):
                return dataclasses.replace(
                    c, page_table=c.page_table.at[..., slot, :].set(row))
            return c

        return jax.tree_util.tree_map(
            upd, carry, is_leaf=lambda x: isinstance(x, PagedKVCache))

    def _derive_prefill_len(self, requests: List[Request]) -> int:
        if self.scfg.prefill_len is not None:
            pad = self.scfg.prefill_len
        else:
            pad = max((len(r.prompt) for r in requests), default=1)
        pad = min(-(-pad // self.scfg.page_size) * self.scfg.page_size,
                  self.slot_buf)
        long = [r.rid for r in requests if len(r.prompt) > pad]
        if long:
            raise ValueError(
                f"requests {long}: prompt exceeds the static prefill "
                f"length {pad} (slot capacity {self.slot_buf})")
        return pad

    # ---- serving loop ------------------------------------------------------

    def run(self, requests: List[Request],
            extras: Optional[Dict[int, dict]] = None,
            forced: Optional[Dict[int, np.ndarray]] = None
            ) -> Dict[int, np.ndarray]:
        """Serve a request trace to completion; returns {rid: tokens}.

        ``extras``: per-rid extras (encdec frames).  ``forced``: per-rid
        teacher-forcing streams — the engine FEEDS the forced tokens but
        records its own picks (and greedy margins, ``self.margins``); used
        by the token-identity tests to compare across near-tied logits.

        Aborted/timed-out requests never appear in the result dict; their
        partial streams live in ``self.scheduler.cancelled``.  Lifecycle
        timestamps (simulated ticks: TTFT/TPOT/goodput/queue depth) land
        in ``self.metrics`` (serve/metrics.py) — one recorder per run.

        With ``scfg.prefix_cache`` on, the scheduler (page pool + radix
        cache) AND the device carry (quantized prefix pages) PERSIST
        across run() calls, so tenants keep warm prefixes between traces;
        results/cancellations/metrics/margins are per-run.
        """
        scfg = self.scfg
        forced = forced or {}
        extras = extras or {}
        chunked = scfg.prefill_chunk is not None
        if self.spec and forced:
            raise NotImplementedError(
                "teacher-forced streams are incompatible with speculative "
                "decoding (the verify block IS the fed stream)")
        sched = self.scheduler if (scfg.prefix_cache and
                                   getattr(self, "scheduler", None)
                                   is not None) else None
        if sched is None:
            sched = Scheduler(self.n_slots, scfg.max_len, scfg.page_size,
                              total_pages=scfg.total_pages,
                              slot_pages=self.n_pages_slot,
                              prefix_cache=scfg.prefix_cache,
                              prefix_cache_pages=scfg.prefix_cache_pages,
                              prefill_chunk=scfg.prefill_chunk,
                              tracer=self.tracer)
            carry = registry.make_decode_state(
                self.cfg, self.n_slots, scfg.max_len,
                kv_cache_format=scfg.kv_cache_format,
                page_size=scfg.page_size, total_pages=sched.total_pages)
            # KV page pools shard their heads axis over the TP axis;
            # page-table rows / lengths stay replicated (host mutates them
            # identically everywhere).  Identity on the 1-device mesh.
            carry = shd.place_serve_cache(carry, self.mesh)
        else:
            carry = self._last_carry    # warm prefix pages persist
            sched.results = {}
            sched.cancelled = {}
        self.scheduler = sched
        for r in requests:
            sched.submit(r)
        met = MetricsRecorder(tracer=self.tracer)
        self.metrics = met
        for r in requests:
            met.submitted(r.rid, r.arrival, deadline=r.deadline)
        if chunked:
            # the chunk/suffix programs have static width prefill_chunk;
            # prompts stream in over ticks, so only slot capacity caps them
            prefill_pad = scfg.prefill_chunk
            long = [r.rid for r in requests
                    if len(r.prompt) > self.slot_buf]
            if long:
                raise ValueError(f"requests {long}: prompt exceeds the "
                                 f"slot capacity {self.slot_buf}")
        else:
            prefill_pad = self._derive_prefill_len(requests)
        # partial-suffix preemption: re-admission suffixes must fit the
        # static prefill pad (chunked mode streams any suffix length)
        sched.resume_pad = None if chunked else prefill_pad

        tokens, rids, steps = self._replicate(
            jnp.zeros((self.n_slots,), jnp.int32),
            jnp.zeros((self.n_slots,), jnp.int32),
            jnp.ones((self.n_slots,), jnp.int32))
        self.margins: Dict[int, list] = {}
        trash_row = np.full((self.n_pages_slot,), TRASH_PAGE, np.int32)
        slot_rid = [None] * self.n_slots
        slot_fed = {}                       # slot -> host index into forced
        pending = {}                        # slot -> (tok, margin) DEVICE
                                            # scalars from prefill, synced
                                            # with the tick's one transfer

        # jit-compile observation: cache-size polling costs a few python
        # attribute reads per tick, so it runs only with a live tracer —
        # the sizes are read, never asserted on, and emission is host-side
        trc = self.tracer
        if trc.enabled:
            jit_progs = [["prefill", self._prefill, 0],
                         ["prefill_suffix", self._prefill_sfx, 0],
                         ["prefill_chunk", self._prefill_chk, 0],
                         ["decode", self._decode, 0],
                         ["verify", self._verify, 0]]
            for rec in jit_progs:
                rec[2] = rec[1]._cache_size()

        tick = 0
        while sched.has_work():
            trc.set_time(tick)
            trc.begin("engine", "tick")
            # -- lifecycle: hard aborts/timeouts due NOW fire before any
            # admission or prefill/decode work is issued this tick
            for slot, rid, stage, reason in sched.expire(tick):
                met.cancelled(rid, tick, stage, reason)
                if slot is not None:        # was on-device: park its row
                    carry = self._set_page_row(carry, slot, trash_row)
                    self.margins.pop(rid, None)
                    slot_rid[slot] = None
                    pending.pop(slot, None)
                    slot_fed.pop(slot, None)

            # -- admissions (host): pages + slot, then ONE prefill program
            # (warm shared-prefix admissions run the SUFFIX program; a
            # later admission in the same batch may share pages a prior
            # one writes, so prefills run strictly in placed order).
            # Chunked mode defers ALL prompt writes to prefill_work below.
            for slot, req, row, pfx in sched.admit(tick):
                carry = self._set_page_row(carry, slot, row)
                slot_rid[slot] = req.rid
                rids = rids.at[slot].set(req.rid)
                met.admitted(req.rid, tick)
                if chunked:
                    continue
                padded = np.zeros((1, prefill_pad), np.int32)
                sfx = np.asarray(req.prompt[pfx:], np.int32)
                padded[0, :len(sfx)] = sfx
                if sched.prefix_cache is not None:
                    # prefix-cache mode: EVERY admission (cold: pfx == 0)
                    # runs the quantize-then-attend suffix program, so the
                    # suffix hidden states are a pure function of the
                    # quantized pages — warm admission is BIT-IDENTICAL to
                    # a cold start of the same prompt, for every page fmt
                    tok, margin, carry = self._prefill_sfx(
                        jnp.asarray(padded), jnp.asarray(len(req.prompt)),
                        jnp.asarray(pfx), jnp.asarray(slot),
                        jnp.asarray(req.rid), carry)
                else:
                    tok, margin, carry = self._prefill(
                        jnp.asarray(padded), jnp.asarray(len(req.prompt)),
                        jnp.asarray(slot), jnp.asarray(req.rid), carry,
                        extras.get(req.rid, {}))
                steps = steps.at[slot].set(1)
                pending[slot] = (tok, margin)
                if req.rid in forced:
                    slot_fed[slot] = 0
                    tokens = tokens.at[slot].set(int(forced[req.rid][0]))
                else:
                    tokens = tokens.at[slot].set(tok)

            # -- chunked prefill: at most ONE chunk per mid-prefill slot
            # per tick, interleaved with this tick's decode.  Chunks
            # attend THROUGH the slot's quantized pages, so the final
            # (short) chunk — which reuses the suffix program, writes the
            # tail rows and samples the first token — produces streams
            # BIT-IDENTICAL to an unchunked admission of the same prompt.
            for slot, req, start, clen, last in sched.prefill_work(tick):
                if not last:
                    chunk = np.asarray(req.prompt[start:start + clen],
                                       np.int32)[None]
                    carry = self._prefill_chk(
                        jnp.asarray(chunk), jnp.asarray(slot),
                        jnp.asarray(start), carry)
                    continue
                padded = np.zeros((1, prefill_pad), np.int32)
                padded[0, :clen] = req.prompt[start:]
                tok, margin, carry = self._prefill_sfx(
                    jnp.asarray(padded), jnp.asarray(len(req.prompt)),
                    jnp.asarray(start), jnp.asarray(slot),
                    jnp.asarray(req.rid), carry)
                steps = steps.at[slot].set(1)
                pending[slot] = (tok, margin)
                if req.rid in forced:
                    slot_fed[slot] = 0
                    tokens = tokens.at[slot].set(int(forced[req.rid][0]))
                else:
                    tokens = tokens.at[slot].set(tok)

            # -- decode tick: no host transfer inside the loop.  Slots
            # still mid-prefill neither emit nor commit (their cache
            # writes are masked to the trash page with frozen lengths).
            active = sched.decoding_slots()
            if self.spec:
                # one verify pass per tick; pages must cover the k
                # CANDIDATE rows, but written advances by the ACCEPTED
                # length only (advance_written, after the host sync)
                T = 1 if active else 0
                growth, preempted = sched.ensure_capacity(
                    scfg.spec_k if active else 0, advance=False)
            else:
                T = sched.tick_steps(scfg.decode_chunk,
                                     {s: 1 for s in pending})
                # demand-driven paging: grow rows for this tick's writes;
                # on pool exhaustion the youngest slot is preempted
                # (requeued, its pages released) — drop its host state
                # and trash its row
                growth, preempted = sched.ensure_capacity(T)
            for slot, row in growth:
                carry = self._set_page_row(carry, slot, row)
            for slot in preempted:
                carry = self._set_page_row(carry, slot, trash_row)
                self.margins.pop(slot_rid[slot], None)
                slot_rid[slot] = None
                pending.pop(slot, None)
                slot_fed.pop(slot, None)
            active = [s for s in active if s not in preempted]
            amask = np.zeros((self.n_slots,), bool)
            amask[active] = True
            amask = self._replicate(jnp.asarray(amask))
            ne = np.zeros((self.n_slots,), np.int32)
            if self.spec and active:
                # -- speculative tick: draft + verify + rollback, one call
                g, margin, ne_d, nxt, steps, carry = self._verify(
                    tokens, carry, rids, steps, amask)
                tokens = nxt
                em_s = np.asarray(g)                  # (n_slots, k)
                mg_s = np.asarray(margin)
                ne = np.asarray(ne_d)
                em = em_s.T                           # commit reads [:, slot]
                mg = mg_s.T
            elif self.spec:
                em = np.zeros((0, self.n_slots), np.int32)
                mg = np.zeros((0, self.n_slots), np.float32)
            else:
                picks, margs = [], []
                for _ in range(T):
                    nxt, margin, steps, carry = self._decode(tokens, carry,
                                                             rids, steps,
                                                             amask)
                    picks.append(nxt)
                    margs.append(margin)
                    tokens = nxt
                    for slot, idx in slot_fed.items():  # teacher forcing
                        stream = forced[slot_rid[slot]]
                        nxt_idx = min(idx + 1, len(stream) - 1)
                        tokens = tokens.at[slot].set(int(stream[nxt_idx]))
                        slot_fed[slot] = nxt_idx

                # ONE host sync per tick: emitted picks + margins + firsts
                em = (np.asarray(jnp.stack(picks, 0)) if picks
                      else np.zeros((0, self.n_slots), np.int32))
                mg = (np.asarray(jnp.stack(margs, 0)) if margs
                      else np.zeros((0, self.n_slots), np.float32))
            first_slots = sorted(pending)
            firsts = {} if not first_slots else dict(zip(first_slots, zip(
                np.asarray(jnp.stack([pending[s][0] for s in first_slots])),
                np.asarray(jnp.stack([pending[s][1] for s in first_slots])))))
            pending.clear()
            emitted_counts = []
            for slot in active:
                rid = slot_rid[slot]
                toks, margins = [], self.margins.setdefault(rid, [])
                if slot in firsts:
                    met.first_token(rid, tick)
                    toks.append(int(firsts[slot][0]))
                    margins.append(float(firsts[slot][1]))
                if self.spec:
                    # variable per-slot advance: the accepted prefix + the
                    # corrected token; written grows by the SAME count so
                    # the high-water mark tracks the rolled-back lengths
                    n = int(ne[slot])
                    sched.advance_written(slot, n)
                    emitted_counts.append(n)
                    toks += [int(t) for t in em[:n, slot]]
                    margins += [float(m) for m in mg[:n, slot]]
                else:
                    toks += [int(t) for t in em[:, slot]]
                    margins += [float(m) for m in mg[:, slot]]
                sched.commit(slot, toks, scfg.eos_id)
                if sched.slots[slot] is None:           # freed: park pages
                    carry = self._set_page_row(carry, slot, trash_row)
                    slot_rid[slot] = None
                    slot_fed.pop(slot, None)
                    met.finished(rid, tick, len(sched.results[rid]))
            if self.spec:
                met.spec_tick(emitted_counts, scfg.spec_k)
            sched.count_tick(T, n_active=len(active))
            met.tick(queue_depth=len(sched.queue), n_active=len(active))
            if trc.enabled:
                for rec in jit_progs:
                    n = rec[1]._cache_size()
                    if n != rec[2]:
                        trc.instant("engine", "jit_compile", program=rec[0],
                                    cache_size=n)
                        trc.counter("jit_compiles", n - rec[2])
                        rec[2] = n
            trc.end("engine", "tick")
            tick += 1

        self.margins = {rid: np.asarray(ms, np.float32)
                        for rid, ms in self.margins.items()}
        met.set_counters(sched.stats)
        self._last_carry = carry    # page-table invariant tests + the
                                    # prefix-cache persistence above
        return dict(sched.results)

    def generate(self, prompts: List[np.ndarray],
                 max_new: int = 32) -> List[np.ndarray]:
        """Lockstep-``Engine``-style convenience: all prompts arrive at
        tick 0; returns outputs in prompt order (stops after EOS)."""
        reqs = [Request(rid=i, prompt=np.asarray(p, np.int32),
                        max_new=max_new) for i, p in enumerate(prompts)]
        res = self.run(reqs)
        return [res[i] for i in range(len(prompts))]


def serve_step_fn(cfg: ModelConfig, qcfg: fqt.QuantConfig):
    """The dry-run's ``serve_step``: one decode token against a full cache.

    Returns f(params, tokens, carry) -> (logits, carry); tokens: (B, 1).
    """

    def serve_step(params, tokens, carry):
        return registry.decode_step(params, cfg, qcfg, tokens, carry)

    return serve_step
