"""Batched serving engine: FP4 forward, prefill + decode with KV caches.

The deployed artifact of the paper's pipeline is an *FP4-forward* model (the
QAF phase keeps the forward path in FP4 precisely so the served model is
FP4-inference-compatible).  The engine therefore runs every weight GEMM
through the same NVFP4 RtN forward quantization used in training — serving
is numerically identical to the training forward pass.

Design (vLLM-style, reduced to the paper's needs):
  * ``prefill``: one full-sequence pass that fills the caches (GQA KV with
    optional SWA rolling buffers, SSM conv/state for hybrid/ssm families).
  * ``decode_step``: one token for every active sequence (B, 1).
  * static-shape batching: requests are padded into fixed (B, S) slots so
    the two compiled programs cover the whole serving life cycle (TPU-
    friendly: no recompilation; slots free as sequences hit EOS/max_len).
  * sampling: greedy or temperature/top-k, PRNG-keyed per request.
  * quantize-once packed weights: GEMM weights are packed to NVFP4 storage
    (uint8 nibble codes + float8 block scales, ~0.56 bytes/param) at
    engine build, so the bandwidth-bound decode path streams 4-bit weights
    from HBM instead of re-fake-quantizing bf16 every token.  Bit-identical
    tokens (serve/packing.py); disable with ``pack_weights=False``.
  * block-quantized KV cache: prefill and decode cache writes are stored
    packed (``ServeConfig.kv_cache_format``: "nvfp4" default, "fp8", or
    the "bf16" escape hatch) and decode attention dequantizes K/V blocks
    on the fly — long-context decode attention streams 0.5625 bytes/elem
    of cache instead of 2 (models/layers.PackedKVCache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fqt
from repro.models import registry
from repro.models.config import ModelConfig
from repro.serve import packing


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_size: int = 8
    max_len: int = 2048
    temperature: float = 0.0      # 0 => greedy
    top_k: int = 0                # 0 => no top-k filtering
    eos_id: int = 2
    seed: int = 0
    # KV cache storage: "nvfp4" (E2M1 nibble codes + f8 block scales along
    # the head dim, 0.5625 bytes/elem, ~3.56x less decode-attention HBM
    # traffic), "fp8" (f8 codes + bf16 block scales, 1.125 bytes/elem), or
    # "bf16" — the unquantized escape hatch.  Cache writes are quantized
    # with RtN (the paper's inference forward rounding); decode attention
    # dequantizes K/V blocks on the fly, never materializing a bf16 cache.
    kv_cache_format: str = "nvfp4"


def _sample(logits: jax.Array, key, scfg: ServeConfig) -> jax.Array:
    """logits: (B, V) -> (B,) int32."""
    if scfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / scfg.temperature
    if scfg.top_k > 0:
        kth = jax.lax.top_k(logits, scfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


class Engine:
    """Single-model serving engine over the uniform registry API."""

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 qcfg: Optional[fqt.QuantConfig] = None,
                 pack_weights: bool = True):
        self.cfg, self.scfg = cfg, scfg
        # serving default: the paper's FP4 forward (RtN), nothing else
        self.qcfg = qcfg if qcfg is not None else fqt.qaf_config()
        if pack_weights and self.qcfg.fwd_w is not None:
            # quantize ONCE: every GEMM weight becomes packed NVFP4 storage;
            # the forward consumes it directly (fqt._packed_forward), token-
            # identical to re-fake-quantizing per GEMM.
            params = packing.pack_model_params(cfg, params, self.qcfg.fwd_w)
        self.params = params

        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))

    # ---- compiled kernels --------------------------------------------------

    def _prefill_impl(self, tokens, carry, extras):
        return registry.prefill(self.params, self.cfg, self.qcfg, tokens,
                                carry, extras=extras)

    def _decode_impl(self, tokens, carry, key):
        logits, carry = registry.decode_step(self.params, self.cfg,
                                             self.qcfg, tokens[:, None],
                                             carry)
        nxt = _sample(logits[:, -1], key, self.scfg)
        return nxt, carry

    # ---- public API ----------------------------------------------------------

    def generate(self, prompts: List[np.ndarray], max_new: int = 32,
                 extras: Optional[dict] = None) -> List[np.ndarray]:
        """Greedy/temperature generation for a batch of token prompts."""
        scfg, cfg = self.scfg, self.cfg
        B = len(prompts)
        if B > scfg.batch_size:
            raise ValueError(f"{B} prompts > batch_size {scfg.batch_size}")
        # pad the batch to the fixed slot count
        plen = max(len(p) for p in prompts)
        toks = np.zeros((scfg.batch_size, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p       # left-pad (simplest static shape)
        toks = jnp.asarray(toks)

        carry = registry.make_decode_state(
            cfg, scfg.batch_size, scfg.max_len,
            kv_cache_format=scfg.kv_cache_format)
        extras = extras or {}
        last_logits, carry = self._prefill(toks, carry, extras)

        key = jax.random.PRNGKey(scfg.seed)
        out = np.zeros((scfg.batch_size, max_new), np.int32)
        done = np.zeros((scfg.batch_size,), bool)
        nxt = _sample(last_logits, key, scfg)
        for t in range(max_new):
            out[:, t] = np.where(done, scfg.eos_id, np.asarray(nxt))
            done |= np.asarray(nxt) == scfg.eos_id
            if done.all():
                out = out[:, : t + 1]
                break
            key, sub = jax.random.split(key)
            nxt, carry = self._decode(jnp.asarray(out[:, t]), carry, sub)
        return [out[i] for i in range(B)]


def serve_step_fn(cfg: ModelConfig, qcfg: fqt.QuantConfig):
    """The dry-run's ``serve_step``: one decode token against a full cache.

    Returns f(params, tokens, carry) -> (logits, carry); tokens: (B, 1).
    """

    def serve_step(params, tokens, carry):
        return registry.decode_step(params, cfg, qcfg, tokens, carry)

    return serve_step
