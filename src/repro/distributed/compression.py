"""SR-quantized gradient compression for the inter-pod all-reduce.

At multi-pod scale the gradient all-reduce crosses the (slow) inter-pod
links while everything else stays on intra-pod ICI.  We compress that hop
with the same machinery the paper builds for FP4 training: block-scaled
low-precision codes with *stochastic rounding*, which keeps the compressed
all-reduce **unbiased** — the paper's §4 analysis (SR noise only adds a
variance term σ_q²·tr(H), no bias floor) applies verbatim to gradient
compression noise, and the same √3 gradient-to-noise threshold tells you
when 8-bit compression stops being safe and the trainer should fall back to
bf16 reduction.

Default format: E4M3 codes + E4M3 block-32 scales (2× the bytes of FP4;
measured σ_q stays ~50× below the gradient threshold for the 7B run — see
EXPERIMENTS.md §Perf).  The collective itself is a ``psum`` inside a
``shard_map`` that is *manual only over the pod axis* — in-pod GSPMD
sharding (FSDP/TP) passes through untouched.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.quantize import BlockQuantSpec, fake_quant
from repro.distributed.compat import shard_map


# E4M3 codes + E4M3 block scales (two-level): the E8M0 floor rule would map
# block maxima into [256, 512) against e4m3's 448 ceiling — a clipping bias
# SR cannot remove.  Two-level amax scaling keeps the compressed all-reduce
# unbiased up to tail clipping only.
GRAD_FP8 = BlockQuantSpec(data_fmt="e4m3", scale_fmt="e4m3", block=32,
                          two_level=True, stochastic=True)
# Aggressive NVFP4 variant (the paper's own format) for bandwidth-starved
# inter-pod links; the √3 monitor decides whether it is safe.
GRAD_FP4 = BlockQuantSpec(data_fmt="e2m1", scale_fmt="e4m3", block=16,
                          two_level=True, stochastic=True)


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = True
    spec: BlockQuantSpec = GRAD_FP8
    # quantize the *result* again after the psum so every pod holds
    # bit-identical gradients (determinism across elastic restarts)
    requantize_result: bool = False


def _leaf_compress_psum(g: jax.Array, key: jax.Array, axis: str,
                        spec: BlockQuantSpec, npods: int) -> jax.Array:
    """Quantize local gradient shard -> psum over pods -> mean."""
    orig_dtype, shape = g.dtype, g.shape
    flat = g.astype(jnp.float32).ravel()
    pad = (-flat.size) % spec.block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    # each pod uses a distinct SR draw (fold in its pod index) so noise
    # averages down across pods instead of adding coherently
    key = jax.random.fold_in(key, jax.lax.axis_index(axis))
    q = fake_quant(flat[None], spec, axis=-1, key=key)[0]
    summed = jax.lax.psum(q, axis)
    out = (summed / npods)[: flat.size - pad if pad else flat.size]
    return out.reshape(shape).astype(orig_dtype)


def compressed_psum_mean(grads, key: jax.Array, axis: str,
                         spec: BlockQuantSpec, npods: int):
    """Compressed mean-all-reduce of a gradient pytree over ``axis``.

    Must run inside a shard_map manual over ``axis``.  Each leaf gets an
    independent SR stream derived from ``key``.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    out = []
    for i, g in enumerate(leaves):
        out.append(_leaf_compress_psum(g, jax.random.fold_in(key, i), axis,
                                       spec, npods))
    return jax.tree_util.tree_unflatten(treedef, out)


def pod_mean_grads(grads, key: jax.Array, mesh: Mesh,
                   cfg: Optional[CompressionConfig]):
    """Average per-pod gradients across the "pod" axis.

    ``grads`` are *per-pod local means* laid out with in-pod GSPMD sharding;
    this wraps the pod-axis reduction in shard_map (manual over "pod" only;
    "data"/"model" stay automatic) and compresses it per ``cfg``.
    Outside shard_map; call from the pjit'd train step.
    """
    if "pod" not in mesh.axis_names:
        return grads
    npods = mesh.devices.shape[mesh.axis_names.index("pod")]
    if npods == 1:
        return grads

    # manual ONLY over "pod": in-pod GSPMD axes stay automatic
    manual = frozenset({"pod"})
    specs = jax.tree_util.tree_map(lambda _: P(), grads)

    if cfg is None or not cfg.enabled:
        fn = lambda g: jax.tree_util.tree_map(
            lambda x: jax.lax.pmean(x, "pod"), g)
        return shard_map(fn, mesh=mesh, in_specs=(specs,),
                         out_specs=specs, axis_names=manual,
                         check_vma=False)(grads)

    fn = partial(compressed_psum_mean, axis="pod", spec=cfg.spec,
                 npods=npods)
    return shard_map(
        lambda g, k: fn(g, k), mesh=mesh,
        in_specs=(specs, P()), out_specs=specs, axis_names=manual,
        check_vma=False)(grads, key)


def compression_ratio(spec: BlockQuantSpec, src_bits: int = 16) -> float:
    """Wire bytes ratio vs uncompressed (bf16) gradients."""
    bits = spec.data.nbits + spec.scale.nbits / spec.block
    return src_bits / bits


# ---- packed-weight collectives (serving FSDP gather) ---------------------------
#
# The gradient wire format above (low-bit codes + block scales) is ALSO the
# right wire format for gathering FSDP-sharded serving weights: a
# ``PackedQuantizedTensor`` already stores uint8 nibble codes + f8 block
# scales, so an all-gather of its leaves moves ~4.5 bits/param (NVFP4:
# 4 + 8/16) instead of 16 for a bf16 weight gather — the per-slice pow2
# tensor scale is replicated and never travels.


def packed_wire_bits_per_param(block: int = 16) -> float:
    """Bits/param an all-gather of packed NVFP4 weights moves (~4.5)."""
    from repro.distributed.specs import packed_wire_bits_per_param as f
    return f(block)


def packed_gather_ratio(block: int = 16, src_bits: int = 16) -> float:
    """bf16-gather bytes / packed-gather bytes (~3.56x for NVFP4)."""
    return src_bits / packed_wire_bits_per_param(block)


def allgather_packed(pt, axis: str, dim: int = 0):
    """All-gather a ``PackedQuantizedTensor`` shard along logical ``dim``
    inside a shard_map manual over ``axis`` — the FSDP-style weight gather
    of sharded serving.

    Only the wire format travels: the uint8 nibble codes directly, and the
    block scales bitcast to uint8 for the hop (f8 collectives are not
    portable across backends; the bytes are identical either way).  ``dim``
    must not be the nibble-packed last axis (shard FSDP on the contraction
    axis, as the sharding rules do).
    """
    import dataclasses as _dc

    import jax.numpy as _jnp
    from repro.core.quantize import PackedQuantizedTensor
    assert isinstance(pt, PackedQuantizedTensor), type(pt)
    dim = dim % pt.ndim
    if dim == pt.ndim - 1:
        raise ValueError("cannot gather along the nibble-packed last axis")
    packed = jax.lax.all_gather(pt.packed, axis, axis=dim, tiled=True)
    if _jnp.dtype(pt.scales.dtype).itemsize == 1:
        sc_u8 = jax.lax.bitcast_convert_type(pt.scales, _jnp.uint8)
        sc_u8 = jax.lax.all_gather(sc_u8, axis, axis=dim, tiled=True)
        scales = jax.lax.bitcast_convert_type(sc_u8, pt.scales.dtype)
    else:                     # non-f8 scale formats: gather as stored
        scales = jax.lax.all_gather(pt.scales, axis, axis=dim, tiled=True)
    return _dc.replace(pt, packed=packed, scales=scales)
