from repro.distributed import compression, pipeline, sharding
