from repro.distributed import compat, compression, pipeline, sharding
from repro.distributed.compat import shard_map
