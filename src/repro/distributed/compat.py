"""JAX version compatibility shims for the distributed layer.

``shard_map`` graduated from ``jax.experimental.shard_map.shard_map`` to
``jax.shard_map`` (with ``axis_names=``/``check_vma=`` replacing the old
``auto=``/``check_rep=`` parameters).  This module exposes one
``shard_map`` callable with the NEW keyword surface that works on both:

  * new JAX (has ``jax.shard_map``): passed through directly;
  * old JAX (e.g. 0.4.x): falls back to
    ``jax.experimental.shard_map.shard_map`` run fully manual.  The
    partially-automatic form (``auto = mesh.axis_names - axis_names``)
    lowers ``axis_index`` to a PartitionId op the 0.4.x SPMD partitioner
    rejects at runtime, so the non-manual axes are made manual too: with
    the specs used in this repo (P() on the auto axes) every device holds
    the full per-shard array and the body's in-scope collectives are
    unchanged — numerically identical, merely without GSPMD resharding
    freedom *inside* the mapped body on old JAX (perf, not correctness).

Use this everywhere instead of reaching for ``jax.shard_map`` so the repo
runs on the full supported JAX range.
"""
from __future__ import annotations

from typing import Optional

import jax

_NEW = getattr(jax, "shard_map", None)


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[frozenset] = None,
              check_vma: bool = False):
    """Version-portable shard_map; ``axis_names`` are the manual axes
    (default: all mesh axes)."""
    if _NEW is not None:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        return _NEW(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_vma=check_vma, **kwargs)
    from jax.experimental.shard_map import shard_map as _old
    return _old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=bool(check_vma))
