"""Pure (jax-free) partition-spec logic for packed serving tensors.

This is the spec *derivation* layer under ``distributed/sharding.py``:
everything here works on plain tuples — mesh-axis names (or ``None``) per
tensor dimension — so the congruence rules can be checked without touching
jax, devices or XLA (``tools/check_env.py --mesh`` runs them standalone).

The core problem it solves: a ``PackedQuantizedTensor`` stores one logical
weight as THREE arrays whose shapes disagree with the logical shape —

  * ``packed``  : uint8 nibble codes, logical shape with the LAST axis
                  halved (two E2M1 values per byte);
  * ``scales``  : f8 block scales, logical shape with the BLOCKING axis
                  divided by ``block``;
  * ``tscale``  : f32 per-batch-slice tensor scales (leading dims only).

A partition spec written against the logical shape must therefore be
re-validated per leaf (the halved/blocked dims change divisibility), and —
crucially — the scale leaf must shard **congruently** with the code leaf:
a mesh axis shards logical dim ``d`` of the scales iff it shards logical
dim ``d`` of the codes.  ``packed_leaf_specs`` derives the scale spec FROM
the code spec, so the two can never diverge; any dim that cannot shard on
every leaf it touches is replicated on all of them, and the drop is
reported as a diagnostic instead of happening silently.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Tuple

Axis = Optional[object]          # None | str | tuple[str, ...]
SpecTuple = Tuple[Axis, ...]

# CLI mesh-spec axes -> mesh axis names used by the sharding rule tables.
MESH_AXIS_FOR = {"tp": "model", "dp": "data", "fsdp": "data"}


def parse_mesh_spec(spec: Optional[str]) -> Dict[str, int]:
    """Parse a ``--mesh`` CLI spec like ``"tp=2"`` or ``"dp=2,tp=4"``.

    Returns ``{mesh_axis_name: size}`` (e.g. ``{"model": 2}``); ``None``
    or ``""`` mean the degenerate single-device mesh ``{"model": 1}``.
    """
    out: Dict[str, int] = {}
    if spec:
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            m = re.fullmatch(r"(\w+)\s*=\s*(\d+)", part)
            if not m or m.group(1) not in MESH_AXIS_FOR:
                raise ValueError(
                    f"bad mesh spec {spec!r}: expected comma-separated "
                    f"{sorted(MESH_AXIS_FOR)} entries like 'tp=2'")
            name = MESH_AXIS_FOR[m.group(1)]
            size = int(m.group(2))
            if size < 1:
                raise ValueError(f"mesh axis {m.group(1)}={size} < 1")
            out[name] = max(out.get(name, 1), size)
    out.setdefault("model", 1)
    return out


def _axes_of(ax: Axis) -> Tuple[str, ...]:
    if ax is None:
        return ()
    return tuple(ax) if isinstance(ax, tuple) else (ax,)


def _axes_size(ax: Axis, axis_sizes: Dict[str, int]) -> Optional[int]:
    """Product of mesh-axis sizes, or None if any axis is absent."""
    total = 1
    for a in _axes_of(ax):
        if a not in axis_sizes:
            return None
        total *= axis_sizes[a]
    return total


def divisible_axes(spec: Sequence[Axis], shape: Sequence[int],
                   axis_sizes: Dict[str, int], path: str = "",
                   drops: Optional[List[str]] = None) -> SpecTuple:
    """Drop spec entries that do not evenly divide ``shape``.

    Pure-tuple version of ``sharding._divisible``: each dropped entry is
    recorded in ``drops`` as a human-readable diagnostic naming the leaf
    ``path`` — silent replication under nibble packing is a correctness-
    adjacent perf bug (a "sharded" deploy quietly holding full replicas).
    """
    fixed: List[Axis] = []
    padded = tuple(spec) + (None,) * (len(shape) - len(spec))
    for d, ax in enumerate(padded):
        if ax is None or (isinstance(ax, tuple) and not ax):
            fixed.append(None)       # empty dp-axes tuple == replicated
            continue
        total = _axes_size(ax, axis_sizes)
        if total is None or total == 1:
            # absent axis: benign; size-1 axis: sharding over it IS
            # replication — normalize to None so specs match what GSPMD
            # reports back (jit-output shardings on a 1-device mesh
            # normalize to P(), and spec equality keys the compile cache)
            fixed.append(None)
            continue
        if shape[d] % total == 0:
            fixed.append(ax)
        else:
            fixed.append(None)
            if drops is not None:
                drops.append(
                    f"{path or '<leaf>'}: dim {d} (size {shape[d]}) not "
                    f"divisible by mesh axis {ax!r} (size {total}) — "
                    f"replicating that dim")
    return strip_trailing_none(fixed)


def strip_trailing_none(spec: Sequence[Axis]) -> SpecTuple:
    """Canonical spec form: ``(None, None)`` == ``()`` to GSPMD, but NOT
    to the jit compile cache's sharding equality — always strip."""
    out = list(spec)
    while out and out[-1] is None:
        out.pop()
    return tuple(out)


def packed_leaf_specs(base_spec: Sequence[Axis], logical_shape: Sequence[int],
                      axis: int, block: int, axis_sizes: Dict[str, int],
                      path: str = "",
                      drops: Optional[List[str]] = None
                      ) -> Dict[str, SpecTuple]:
    """Derive congruent leaf specs for one ``PackedQuantizedTensor``.

    ``base_spec`` is the logical-shape partition spec (the same rule table
    that shards the unpacked bf16 weight).  Returns specs for the three
    leaves, with the invariant that a mesh axis appears on logical dim
    ``d`` of EVERY leaf that carries dim ``d``, or on none of them:

      * codes shard dim d only if it also divides the nibble-packed size
        (d == last: ``logical[-1] // 2``);
      * scales shard dim d only if it also divides the blocked size
        (d == axis: ``logical[axis] // block``);
      * tscale carries only the leading batch dims (``tscale_ndim``).

    The scale spec is DERIVED from the code spec — never computed from a
    separate rule — so the two cannot diverge.
    """
    nd = len(logical_shape)
    axis = axis % nd
    base = tuple(base_spec) + (None,) * (nd - len(base_spec))

    packed_shape = tuple(logical_shape[:-1]) + (logical_shape[-1] // 2,)
    scales_shape = tuple(s // block if d == axis else s
                         for d, s in enumerate(logical_shape))

    code_spec: List[Axis] = []
    for d, ax in enumerate(base):
        if ax is None:
            code_spec.append(None)
            continue
        total = _axes_size(ax, axis_sizes)
        if total is None or total == 1:  # see divisible_axes: size-1 ==
            code_spec.append(None)       # replicated, normalized to None
            continue
        # keep the axis only if EVERY leaf carrying this logical dim
        # shards evenly (congruence by construction)
        ok = packed_shape[d] % total == 0 and scales_shape[d] % total == 0 \
            and logical_shape[d] % total == 0
        if ok:
            code_spec.append(ax)
        else:
            code_spec.append(None)
            if drops is not None:
                drops.append(
                    f"{path or '<leaf>'}: logical dim {d} "
                    f"(size {logical_shape[d]}, packed {packed_shape[d]}, "
                    f"scales {scales_shape[d]}) not divisible by mesh axis "
                    f"{ax!r} (size {total}) on every packed leaf — "
                    f"replicating that dim")

    scale_spec = strip_trailing_none(code_spec)   # derived: congruent
    tscale_ndim = nd - 2                 # pack_quantize(batch_dims=ndim-2)
    tscale_spec = strip_trailing_none(code_spec[:tscale_ndim])
    return {"packed": strip_trailing_none(code_spec), "scales": scale_spec,
            "tscale": tscale_spec}


def congruent(code_spec: Sequence[Axis], scale_spec: Sequence[Axis]) -> bool:
    """True iff the two specs name the same mesh axes per logical dim
    (trailing Nones ignored) — the invariant ``packed_leaf_specs`` keeps."""
    n = max(len(code_spec), len(scale_spec))
    a = tuple(code_spec) + (None,) * (n - len(code_spec))
    b = tuple(scale_spec) + (None,) * (n - len(scale_spec))
    return all(_axes_of(x) == _axes_of(y) for x, y in zip(a, b))


# Wire-format accounting for packed-weight collectives: an FSDP-style
# all-gather of a PackedQuantizedTensor moves uint8 nibble codes (4 bits
# per logical param) plus f8 block scales (8 bits per ``block`` params) —
# ~4.5 bits/param for NVFP4 (block 16) vs 16 for a bf16 gather.
def packed_wire_bits_per_param(block: int = 16, code_bits: int = 4,
                               scale_bits: int = 8) -> float:
    return code_bits + scale_bits / block


def packed_gather_ratio(block: int = 16, src_bits: int = 16) -> float:
    """bf16-gather bytes / packed-gather bytes (~3.56x for NVFP4)."""
    return src_bits / packed_wire_bits_per_param(block)
