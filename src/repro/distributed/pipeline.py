"""Pipeline parallelism: GPipe schedule over a "pipe" mesh axis.

The production configs default to FSDP×TP (the scanned layer stack keeps
HLO size O(1) in depth), but at >512-chip scale the FSDP all-gather of
llama3-405B-class weights becomes the dominant collective.  This module
provides the alternative: split the layer stack into ``pipe`` stages held
on different devices and stream microbatches through with
``collective_permute`` — the inter-stage hop is a point-to-point transfer
of one microbatch's activations instead of an all-gather of weights.

Implementation: a ``shard_map`` manual over the "pipe" axis.  Each stage
holds ``L/S`` layers (the stacked-params leading axis is sharded on
"pipe"); a ``lax.scan`` over ``M + S - 1`` ticks advances the classic GPipe
diagonal: at tick t, stage s processes microbatch ``t - s`` (bubble ticks
compute garbage that is masked on collection).  Backward is ordinary
autodiff through the scan — reverse-mode turns each ppermute into its
inverse, which reproduces the backward pipeline schedule.

Composability: "data"/"model" axes stay automatic inside the shard_map, so
in-stage FSDP/TP sharding (distributed/sharding.py) passes through, giving
DP × PP × TP 3-D parallelism.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    n_microbatches: int
    axis: str = "pipe"


def _stage_specs(params, axis):
    """Stacked layer params (leading L axis) are split across stages."""
    return jax.tree_util.tree_map(
        lambda x: P(axis, *(None,) * (x.ndim - 1)), params)


def pipeline_apply(layer_fn: Callable, stacked_params, x: jax.Array,
                   mesh: Mesh, cfg: PipelineConfig) -> jax.Array:
    """Run ``x`` through L stacked layers split over ``cfg.n_stages`` stages.

    layer_fn(per_layer_params, h) -> h, applied ``L/S`` times per stage via
    an inner scan.  x: (B, ...) with B divisible by n_microbatches.
    Returns the transformed activations, same shape as x.
    """
    S, M, axis = cfg.n_stages, cfg.n_microbatches, cfg.axis
    B = x.shape[0]
    if B % M:
        raise ValueError(f"batch {B} not divisible by {M} microbatches")
    mb = B // M

    def stage_inner(stage_params, h):
        # apply this stage's L/S layers (scan keeps HLO size constant)
        def body(h, lp):
            return layer_fn(lp, h), None

        h, _ = jax.lax.scan(body, h, stage_params)
        return h

    def pipelined(stage_params, xs):
        # xs: (M, mb, ...) this is per-pipe-shard full batch (batch is NOT
        # sharded on "pipe"; DP axes handle batch)
        sid = jax.lax.axis_index(axis)
        nticks = M + S - 1
        buf = jnp.zeros((mb,) + xs.shape[2:], xs.dtype)
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (clamped; bubbles masked later)
            feed = xs[jnp.minimum(t, M - 1)]
            h_in = jnp.where(sid == 0, feed, buf)
            h_out = stage_inner(stage_params, h_in)
            # pass to the next stage
            perm = [(i, (i + 1) % S) for i in range(S)]
            buf_next = jax.lax.ppermute(h_out, axis, perm)
            # last stage emits microbatch t - (S - 1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            emit = (t >= S - 1) & (sid == S - 1)
            outs = jax.lax.cond(
                emit, lambda o: o.at[out_idx].set(h_out), lambda o: o, outs)
            return (buf_next, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(nticks, dtype=jnp.int32))
        # every pipe shard returns outs; only the last stage's is real —
        # broadcast it back (psum of masked copies)
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    xs = x.reshape((M, mb) + x.shape[1:])
    out = shard_map(
        pipelined, mesh=mesh,
        in_specs=(_stage_specs(stacked_params, axis), P()),
        out_specs=P(), axis_names=frozenset({axis}), check_vma=False,
    )(stacked_params, xs)
    return out.reshape(x.shape)


def make_pipeline_mesh(n_stages: int, total_devices: int | None = None):
    """A (pipe, data) mesh over the available devices (testing helper)."""
    n = total_devices or len(jax.devices())
    if n % n_stages:
        raise ValueError(f"{n} devices not divisible into {n_stages} stages")
    return jax.make_mesh((n_stages, n // n_stages), ("pipe", "data"))
