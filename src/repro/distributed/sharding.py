"""GSPMD sharding rules for every model family in the zoo.

The production mesh (launch/mesh.py) is ("data", "model") per pod, with an
outer "pod" axis for multi-pod jobs.  We use a MaxText-style two-level
scheme expressed directly as PartitionSpecs:

  * TP   — the hidden/ffn/head/vocab dimension of each weight is sharded on
           "model" (16-way tensor parallelism inside a pod).
  * FSDP — the d_model dimension of each weight is sharded on "data"
           (ZeRO-3: weights, master copies and Adam moments are all sharded;
           GSPMD inserts the per-layer all-gathers / reduce-scatters).
  * DP   — the batch dimension of activations is sharded on ("pod", "data");
           weights are *replicated across pods* so the only inter-pod
           traffic is the gradient all-reduce (which is where the FP8+SR
           gradient compression of distributed/compression.py applies).
  * SP   — long-context decode shards the KV-cache sequence dimension on
           "model" (sequence parallelism; attention runs distributed flash
           over the cache).

Rules are name+rank based, resolved per parameter leaf, so one table covers
all six families (dense / moe / vlm / hybrid / ssm / encdec) including their
lax.scan-stacked layer dimensions (a leading L axis mapped to None).
"""
from __future__ import annotations

import contextlib
import contextvars
import logging
import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.quantize import PackedQuantizedTensor
from repro.distributed import specs as pspecs

logger = logging.getLogger(__name__)


# ---- axis helpers -------------------------------------------------------------


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel (batch) mesh axes: ('pod','data') or ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_axis(mesh: Mesh) -> Optional[str]:
    return "data" if "data" in mesh.axis_names else None


def tp_axis(mesh: Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


# ---- parameter rules ----------------------------------------------------------

# (regex on the slash-joined tree path, spec builder for the *trailing* 2
# dims).  IN = d_model-like input dim -> FSDP("data"); OUT = hidden-like
# output dim -> TP("model").  Leading stacked dims (scan L, experts E when
# not EP) map to None.
#   kind "io":  (..., IN, OUT)   e.g. wq, w_gate, in_proj
#   kind "oi":  (..., OUT, IN)   e.g. wo, w_down, out_proj
#   kind "vocab_d": (V, d)       embed tables
#   kind "d_vocab": (d, V)       lm_head
#   kind "vec_out": (..., OUT)   biases/smooth living in the hidden dim
#   kind "rep": replicated
_PARAM_RULES = (
    (r"(^|/)(wq|wk|wv|w_gate|w_up|w_in|w_ff_gate|w_ff_up|in_proj|w_gates"
     r"|r_gates|w_q|w_k|w_v|w_if)$", "io"),
    (r"(^|/)(wo|w_down|w_out|out_proj|w_ff_down)$", "oi"),
    (r"(^|/)router$", "d_rep"),          # (..., d, E): router stays tiny
    (r"(^|/)(embed|pos_dec|pos_enc)$", "vocab_d"),
    (r"(^|/)lm_head$", "d_vocab"),
    (r"(^|/)(bq|bk|bv|b_in|b_out|smooth)$", "vec_out"),
    (r"(^|/)(conv_w)$", "vec_out"),      # (..., K, C): C is hidden-like
    (r".*", "rep"),                      # norms, gates, A_log, dt_bias, ...
)


def _spec_for(kind: str, ndim: int, mesh: Mesh,
              expert_parallel: bool = False) -> P:
    fsdp, tp = fsdp_axis(mesh), tp_axis(mesh)
    lead = (None,) * (ndim - 2)
    if kind == "io":
        return P(*lead, fsdp, tp) if ndim >= 2 else P(tp)
    if kind == "oi":
        return P(*lead, tp, fsdp) if ndim >= 2 else P(tp)
    if kind == "d_rep":
        return P(*lead, fsdp) if ndim >= 2 else P()
    if kind == "vocab_d":
        return P(tp, fsdp)
    if kind == "d_vocab":
        return P(fsdp, tp)
    if kind == "vec_out":
        return P(*((None,) * (ndim - 1)), tp)
    return P()


def param_spec(path: str, ndim: int, mesh: Mesh) -> P:
    for pat, kind in _PARAM_RULES:
        if re.search(pat, path):
            return _spec_for(kind, ndim, mesh)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _divisible(spec: P, shape, mesh: Mesh, path: str = "",
               strict: bool = False) -> P:
    """Drop mesh axes that do not divide the corresponding dim (jit allows
    uneven shardings, but padded weight shards waste memory and make the
    roofline numbers lie — prefer replication for the odd dims).

    When ``path`` names the leaf (parameter/cache shardings do), every
    dropped axis is DIAGNOSED — logged, or raised with ``strict=True`` —
    instead of silently replicating: under nibble packing the trailing
    axis is halved, and a "sharded" deploy quietly holding full replicas
    is a correctness-adjacent perf bug.  Anonymous calls (activation
    constraints, where odd smoke-config dims are routine) stay silent.
    """
    drops: list = [] if path else None
    fixed = pspecs.divisible_axes(tuple(spec), tuple(shape),
                                  _mesh_axis_sizes(mesh), path=path,
                                  drops=drops)
    if drops:
        if strict:
            raise ValueError("; ".join(drops))
        for d in drops:
            logger.warning("sharding: %s", d)
    return P(*fixed)


def params_shardings(params, mesh: Mesh):
    """NamedSharding pytree for a parameter pytree (rank+name rules)."""

    def one(path, x):
        spec = param_spec(_path_str(path), x.ndim, mesh)
        return NamedSharding(mesh, _divisible(spec, x.shape, mesh,
                                              path=_path_str(path)))

    return jax.tree_util.tree_map_with_path(one, params)


def params_specs(params, mesh: Mesh):
    def one(path, x):
        return _divisible(param_spec(_path_str(path), x.ndim, mesh),
                          x.shape, mesh, path=_path_str(path))

    return jax.tree_util.tree_map_with_path(one, params)


# ---- activations / batch / optimizer ------------------------------------------


def batch_spec(mesh: Mesh) -> P:
    """(B, S) token batches: batch over DP axes."""
    return P(dp_axes(mesh))


def batch_shardings(batch_struct, mesh: Mesh):
    dp = dp_axes(mesh)

    def one(x):
        spec = P(dp) if x.shape and x.shape[0] % _axes_size(mesh, dp) == 0 \
            else P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, batch_struct)


def _axes_size(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return max(n, 1)


def opt_state_shardings(opt_state, params_shards, mesh: Mesh):
    """AdamW state: master/m/v follow the parameter sharding; step scalar
    replicated."""
    import dataclasses  # noqa: F401
    from repro.optim.adamw import AdamWState

    rep = NamedSharding(mesh, P())
    return AdamWState(
        step=rep,
        master=params_shards,
        m=params_shards,
        v=params_shards,
    )


# ---- activation sharding constraints -------------------------------------------
#
# Parameter shardings alone do not pin down the activation layout: the embed
# table's (vocab→TP, d→FSDP) sharding would otherwise leak `d→data` into the
# residual stream and kick the batch off the "data" axis (replicating every
# (B,S,·) tensor 16×).  Model code therefore calls ``constrain(x, kind)`` at
# the canonical points; it is a no-op unless a launcher opened an
# ``activation_sharding_scope`` (smoke tests / single-device runs unaffected).
#
# Modes:
#   "replicated" — residual stream (B,S,d) = P(dp, None, None): classic
#                  Megatron TP (norms/residual replicated across "model").
#   "sp"         — residual stream = P(dp, "model", None): Megatron-style
#                  sequence parallelism; 16× smaller saved activations, same
#                  wire bytes (all-gather+reduce-scatter replaces all-reduce).

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding_scope(mesh: Mesh, mode: str = "sp"):
    if mode not in ("replicated", "sp"):
        raise ValueError(mode)
    tok = _ACT_CTX.set((mesh, mode))
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def _constrain_spec(kind: str, shape, mesh: Mesh, mode: str) -> Optional[P]:
    dp, tp = dp_axes(mesh), tp_axis(mesh)
    nd = len(shape)
    if kind == "res":        # (B, S, d) residual stream
        seq = tp if (mode == "sp" and nd >= 3) else None
        spec = (dp, seq) + (None,) * (nd - 2)
    elif kind == "hidden":   # (..., f) TP on the trailing hidden dim
        spec = (dp,) + (None,) * (nd - 2) + (tp,)
    elif kind == "heads":    # (B, S, H, D) TP on heads
        spec = (dp,) + (None,) * (nd - 3) + (tp, None)
    elif kind == "logits":   # (B, S, V) TP on vocab
        spec = (dp,) + (None,) * (nd - 2) + (tp,)
    elif kind == "tokens":   # (T, d) flattened token table (MoE)
        spec = (dp,) + (None,) * (nd - 1)
    elif kind == "experts":  # (E, C, d) expert dispatch buffers
        spec = (None, dp) + (None,) * (nd - 2)
    elif kind == "groups":   # (G, ...) MoE group-limited dispatch: G -> dp
        spec = (dp,) + (None,) * (nd - 1)
    elif kind == "qblocks":  # (B, nq, qc, KVH, G, D) flash-attention blocks
        # TP on heads when divisible, else context-parallel on q blocks
        tp_size = _axes_size(mesh, (tp,)) if tp else 1
        if nd == 6 and tp and (shape[3] * shape[4]) % tp_size == 0:
            # shard the larger of (KVH, G) — one must absorb the axis
            if shape[3] % tp_size == 0:
                spec = (dp, None, None, tp, None, None)
            elif shape[4] % tp_size == 0:
                spec = (dp, None, None, None, tp, None)
            else:
                spec = (dp, tp, None, None, None, None)
        else:
            spec = (dp, tp) + (None,) * (nd - 2)
    else:
        raise ValueError(f"unknown constraint kind {kind!r}")
    return _divisible(P(*spec), shape, mesh)


def constrain(x, kind: str):
    """with_sharding_constraint(x, rule) under the active scope; else x."""
    ctx = _ACT_CTX.get()
    if ctx is None or not hasattr(x, "shape") or x.ndim < 2:
        return x
    mesh, mode = ctx
    spec = _constrain_spec(kind, x.shape, mesh, mode)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---- mesh-native serving -------------------------------------------------------
#
# The serving stack places everything under ONE explicit Mesh; a 1-device
# mesh is the degenerate case of the same code path (device_put with a
# replicated spec on one device is the identity), so the engines carry no
# ``if sharded:`` forks.  Axes: "model" is the serving TP axis (heads /
# hidden / vocab), optional "data" is an FSDP-style axis over which packed
# weights are gathered at ~4.5 bits/param (distributed/compression.py).


def make_serve_mesh(spec: Optional[str] = None, *, devices=None) -> Mesh:
    """Build the serving mesh from a ``--mesh`` CLI spec ("tp=2", ...).

    ``None``/empty means the degenerate 1-device mesh over the default
    device — the unsharded engine IS this mesh's special case.
    """
    sizes = pspecs.parse_mesh_spec(spec)
    axes = tuple(a for a in ("data", "model") if a in sizes)
    shape = tuple(sizes[a] for a in axes)
    need = int(np.prod(shape))
    devices = list(jax.devices()) if devices is None else list(devices)
    if need > len(devices):
        raise ValueError(
            f"mesh spec {spec!r} needs {need} devices, have "
            f"{len(devices)}; on CPU force host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"(set BEFORE jax initializes)")
    return Mesh(np.array(devices[:need]).reshape(shape), axes)


def spec_for_packed(path: str, leaf: PackedQuantizedTensor,
                    mesh: Mesh) -> dict:
    """Partition specs for the three leaves of one packed weight.

    The code (``packed``) spec comes from the SAME rule table that shards
    the unpacked bf16 weight, re-validated against the nibble-halved and
    scale-blocked leaf shapes; the ``scales``/``tscale`` specs are DERIVED
    from the code spec (distributed/specs.packed_leaf_specs), so block-
    scale axes always shard congruently with code axes — they cannot
    diverge.  Returns ``{"packed": P, "scales": P, "tscale": P}``.
    """
    base = param_spec(path, leaf.ndim, mesh)
    drops: list = []
    out = pspecs.packed_leaf_specs(tuple(base), tuple(leaf.shape), leaf.axis,
                                   leaf.block, _mesh_axis_sizes(mesh),
                                   path=path, drops=drops)
    for d in drops:
        logger.warning("sharding: %s", d)
    return {k: P(*v) for k, v in out.items()}


def place_serve_params(params, mesh: Mesh):
    """device_put a (possibly packed) parameter pytree under ``mesh``.

    Packed leaves get ``spec_for_packed`` shardings on their nibble-code /
    block-scale / tensor-scale arrays; plain leaves follow ``param_spec``.
    On a 1-device mesh this is the identity placement.
    """

    def one(path, leaf):
        p = _path_str(path)
        if isinstance(leaf, PackedQuantizedTensor):
            sh = spec_for_packed(p, leaf, mesh)
            return leaf.map_leaves(
                lambda name, x: jax.device_put(
                    x, NamedSharding(mesh, sh[name])))
        if not hasattr(leaf, "ndim"):
            return leaf
        spec = _divisible(param_spec(p, leaf.ndim, mesh), leaf.shape, mesh,
                          path=p)
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(
        one, params, is_leaf=lambda x: isinstance(x, PackedQuantizedTensor))


def serve_cache_shardings(cache_struct, mesh: Mesh):
    """Shardings for serving decode state under the serving mesh.

    ``PagedKVCache`` physical page pools (``(…, P, page, KVH, Dc)`` codes
    and scales) shard their KV-heads axis over the TP axis ("model") —
    each device holds the KV pages of its own heads, exactly the heads it
    attends with under Megatron TP.  Page-table rows and lengths are tiny
    int32 host-managed state and stay replicated (the host mutates them
    identically everywhere).  All other cache leaves are replicated.
    """
    tp = tp_axis(mesh)
    from repro.models.layers import PagedKVCache

    def pool_spec(x, path):
        spec = [None] * x.ndim
        if tp is not None and x.ndim >= 2:
            spec[PagedKVCache.HEADS_AXIS] = tp
            return _divisible(P(*spec), x.shape, mesh, path=path)
        return P(*spec)

    def one(path, leaf):
        p = _path_str(path)
        if isinstance(leaf, PagedKVCache):
            import dataclasses as _dc
            return _dc.replace(
                leaf,
                k_codes=NamedSharding(mesh, pool_spec(leaf.k_codes,
                                                      p + "/k_codes")),
                k_scales=NamedSharding(mesh, pool_spec(leaf.k_scales,
                                                       p + "/k_scales")),
                v_codes=NamedSharding(mesh, pool_spec(leaf.v_codes,
                                                      p + "/v_codes")),
                v_scales=NamedSharding(mesh, pool_spec(leaf.v_scales,
                                                       p + "/v_scales")),
                page_table=NamedSharding(mesh, P()),
                lengths=NamedSharding(mesh, P()))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(
        one, cache_struct, is_leaf=lambda x: isinstance(x, PagedKVCache))


def place_serve_cache(cache, mesh: Mesh):
    """device_put serving decode state under ``mesh`` (identity on 1 dev)."""
    shards = serve_cache_shardings(cache, mesh)
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    shard_leaves = jax.tree_util.tree_leaves(shards)
    placed = [jax.device_put(x, s) for x, s in zip(leaves, shard_leaves)]
    return jax.tree_util.tree_unflatten(treedef, placed)


def constrain_serve_cache(cache, mesh: Mesh):
    """In-jit counterpart of ``place_serve_cache``: annotate the carry a
    compiled serving program RETURNS with the same shardings its inputs
    were placed under, so every subsequent call sees identical input
    shardings (the engines' no-recompile guarantee holds on any mesh).
    Pure layout annotation — leaf values are untouched."""
    shards = serve_cache_shardings(cache, mesh)
    leaves, treedef = jax.tree_util.tree_flatten(cache)
    shard_leaves = jax.tree_util.tree_leaves(shards)
    out = [jax.lax.with_sharding_constraint(x, s)
           for x, s in zip(leaves, shard_leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ---- KV cache / decode state ---------------------------------------------------


def cache_specs(cache_struct, mesh: Mesh, batch: int):
    """Sharding for serving state.

    KV caches (B, S, KVH, D): batch on DP axes when divisible, sequence on
    "model" (SP — the 32k/500k caches dominate HBM).  SSM states
    (B, H, P, N): heads on "model".  Conv states and small tensors follow
    batch-only sharding.  Works on the registry's cache pytrees (stacked
    KVCache dataclasses, dicts of ssm/conv states, tuples).
    """
    dp = dp_axes(mesh)
    tp = tp_axis(mesh)
    dp_ok = batch % _axes_size(mesh, dp) == 0

    def one(x):
        shape = x.shape
        bspec = dp if (dp_ok and len(shape) and shape[0] in (batch,)) else None
        # stacked-by-layer caches have shape (L, B, ...) — detect batch pos
        bdim = 0
        if len(shape) >= 2 and shape[0] != batch and shape[1] == batch:
            bdim = 1
        spec = [None] * len(shape)
        if bspec is not None and len(shape) > bdim and shape[bdim] == batch:
            spec[bdim] = dp
        # shard the longest remaining dim on "model" if divisible (SP for
        # seq, head-parallel for SSM states)
        if tp is not None and len(shape) > bdim + 1:
            rest = [(d, s) for d, s in enumerate(shape) if d > bdim]
            d_best, s_best = max(rest, key=lambda t: t[1])
            if s_best % _axes_size(mesh, (tp,)) == 0 and s_best > 1:
                spec[d_best] = tp
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, cache_struct)
