"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B scaled; hf] — 128 experts top-8,
GQA kv=4, qk-norm, per-expert d_ff=1536."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=128,
    n_experts=128, top_k=8,
    moe_groups=16,   # GShard-style group-limited dispatch (DP-local sort)
    rope_theta=1e6, act="swiglu", use_qk_norm=True,
)
