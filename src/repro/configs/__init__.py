"""Assigned architecture configs (+ the paper's own Llama2 sizes).

Each module exposes ``CONFIG``; ``get_config(name)`` resolves by arch id.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, SHAPES, SHAPES_BY_NAME  # noqa

ARCH_IDS = (
    "mixtral_8x7b",
    "qwen3_moe_235b_a22b",
    "whisper_base",
    "internvl2_26b",
    "zamba2_1p2b",
    "qwen2p5_32b",
    "codeqwen1p5_7b",
    "tinyllama_1p1b",
    "llama3_405b",
    "xlstm_125m",
    # the paper's own model family
    "llama2_7b",
    "llama2_350m",
    "llama2_60m",
)

_ALIASES = {
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "whisper-base": "whisper_base",
    "internvl2-26b": "internvl2_26b",
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen2.5-32b": "qwen2p5_32b",
    "codeqwen1.5-7b": "codeqwen1p5_7b",
    "tinyllama-1.1b": "tinyllama_1p1b",
    "llama3-405b": "llama3_405b",
    "xlstm-125m": "xlstm_125m",
    "llama2-7b": "llama2_7b",
    "llama2-350m": "llama2_350m",
    "llama2-60m": "llama2_60m",
}


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))
    if mod_name not in ARCH_IDS:
        raise ValueError(f"unknown arch {name!r}; have {list(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
