"""InternVL2-26B [arXiv:2404.16821; hf] — InternViT frontend (stub patch
embeddings) + InternLM2-20B-style LM backbone."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553,  # padded to 92672
    vision_tokens=256,             # stub InternViT pixel-unshuffled tokens
    rope_theta=1e6, act="swiglu",
)
