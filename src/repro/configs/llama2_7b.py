"""Llama2-7B — the paper's main FP4 experiment (Fig. 6, Table 3)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=32000,
    act="smooth_swiglu",   # paper setup: Smooth-SwiGLU [9]
)
