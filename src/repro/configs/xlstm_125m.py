"""xLSTM-125M [arXiv:2405.04517] — mLSTM blocks with every 4th sLSTM.
d_ff=0 per assignment: FFN width comes from proj_factor inside the blocks."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_every=4, proj_factor=2.0, act="swiglu",
)
