"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 backbone + shared attention
block every 6 layers (MHA: kv == heads)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_conv=4, ssm_expand=2, n_ssm_heads=64,
    attn_every=6, act="swiglu",
)
