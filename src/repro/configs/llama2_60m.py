"""Llama2-style 60M — the paper's threshold-validation scale (Fig. 5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-60m", family="dense",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=1408, vocab_size=32000,
    act="smooth_swiglu",
)
