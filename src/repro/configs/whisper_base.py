"""Whisper-base [arXiv:2212.04356] — enc-dec; conv/mel frontend is a stub
(input_specs provides precomputed frame embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, enc_layers=6, enc_seq=1500,
    d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865,   # padded to 51968 for TP divisibility
    act="gelu",
)
