"""Mixtral 8x7B [arXiv:2401.04088; hf] — MoE 8 experts top-2, GQA kv=8, SWA."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    n_experts=8, top_k=2,
    # moe_groups intentionally NOT set: measured on the dry-run, group-
    # limited dispatch HURTS the 8-expert case (t_coll 54->181 s on
    # prefill_32k: 16 per-group scatter buffers dwarf the small global
    # sort) while it is a 3.8x win for qwen3's 128 experts.  See
    # EXPERIMENTS.md §Perf iteration 5 (refuted hypothesis).
    sliding_window=4096,
    rope_theta=1e6, act="swiglu",
)
