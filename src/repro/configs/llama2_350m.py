"""Llama2-style 350M — the paper's ablation scale (Figs. 1-3)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-350m", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2816, vocab_size=32000,
    act="smooth_swiglu",
)
