"""Llama3-405B [arXiv:2407.21783] — dense GQA kv=8, 128k vocab."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab_size=128256,
    rope_theta=5e5, act="swiglu",
)
