"""Pallas TPU kernels: block-scaled FP4 matmul (unfused and fused-quant).

TPU-native adaptation of Blackwell's block-scaled FP4 MMA (DESIGN.md §3):

  * ``block_matmul``: consumes pre-quantized (codes, scales) operands; each
    grid step loads (TM,TK)/(TK,TN) tiles into VMEM, dequantizes in VREGs
    (codes * broadcast(scales) — exact in bf16), and feeds the MXU with an
    fp32-accumulating dot.  Accumulation runs over the innermost K grid axis
    into the output tile (revisited, standard Pallas matmul pattern).

  * ``fused_quant_matmul``: additionally quantizes *raw* bf16/f32 operand
    tiles on the fly (amax -> scale -> codes in VREGs, RtN or SR with
    explicit random bits), so quantization costs zero extra HBM traffic.
    This is the kernel the FQT layer uses for all three training GEMMs
    (operands pre-transposed so blocks always lie along the contraction
    axis: A (M,K) blocked along K/axis-1, B (K,N) blocked along K/axis-0).

Tile defaults (TM,TN,TK)=(128,128,512): MXU-aligned (128 lanes), VMEM use
~1.2 MB for the fused kernel at fp32 — comfortably within the ~16 MB/core
budget while leaving room for double buffering.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import BlockQuantSpec
from repro.kernels import common as c
from repro.kernels.nvfp4_quant import _pick_tile


# ---- unfused: pre-quantized operands ----------------------------------------


def _block_matmul_kernel(ac_ref, as_ref, bc_ref, bs_ref, o_ref, *, block: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ac = ac_ref[...].astype(jnp.float32)          # (TM, TK)
    bc = bc_ref[...].astype(jnp.float32)          # (TK, TN)
    asc = as_ref[...]                             # (TM, TK//B)
    bsc = bs_ref[...]                              # (TK//B, TN)
    tm, tk = ac.shape
    tn = bc.shape[1]
    nb = tk // block
    ad = (ac.reshape(tm, nb, block) * asc[:, :, None]).reshape(tm, tk)
    bd = (bc.reshape(nb, block, tn) * bsc[:, None, :]).reshape(tk, tn)
    o_ref[...] += jnp.dot(ad, bd, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("block", "interpret", "tm", "tn", "tk"))
def block_matmul(a_codes, a_scales, b_codes, b_scales, tscale, *,
                 block: int = 16, interpret: bool = False,
                 tm: int = 128, tn: int = 128, tk: int = 512) -> jax.Array:
    """(M,K) @ (K,N) with per-block scales; returns fp32 (M,N) * tscale."""
    M, K = a_codes.shape
    K2, N = b_codes.shape
    assert K == K2, (a_codes.shape, b_codes.shape)
    TM, TN = _pick_tile(M, tm), _pick_tile(N, tn)
    TK = _pick_tile(K, tk, block)
    grid = (M // TM, N // TN, K // TK)

    out = pl.pallas_call(
        functools.partial(_block_matmul_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TM, TK), lambda i, j, k: (i, k)),
            pl.BlockSpec((TM, TK // block), lambda i, j, k: (i, k)),
            pl.BlockSpec((TK, TN), lambda i, j, k: (k, j)),
            pl.BlockSpec((TK // block, TN), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a_codes, a_scales, b_codes, b_scales)
    return out * tscale


# ---- fused: quantize raw tiles on the fly, then MMA -------------------------


def _quant_tile_along_last(x, rb, tscale, *, block, data_p, scale_p,
                           scale_is_e8m0, stochastic):
    """Quantize (R, C) tile with blocks along C; returns dequantized tile
    (codes*scales, tscale NOT applied — folded into the output epilogue)."""
    r, ccols = x.shape
    nb = ccols // block
    xb = x.reshape(r, nb, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    if scale_is_e8m0:
        scales = c.e8m0_block_scale_k(absmax, data_p.emax)
    else:
        scales = c.generic_block_scale_k(absmax, data_p.max, scale_p, tscale)
    scaled = xb / (scales[:, :, None] * tscale)
    if stochastic:
        u = c.uniform_from_bits_k(rb).reshape(r, nb, block)
        codes = c.quantize_sr_k(scaled, data_p, u)
    else:
        codes = c.quantize_rtn_k(scaled, data_p)
    return (codes * scales[:, :, None]).reshape(r, ccols)


def _quant_tile_along_first(x, rb, tscale, *, block, data_p, scale_p,
                            scale_is_e8m0, stochastic):
    """Quantize (R, C) tile with blocks along R (no VREG transposes)."""
    r, ccols = x.shape
    nb = r // block
    xb = x.reshape(nb, block, ccols)
    absmax = jnp.max(jnp.abs(xb), axis=1)                 # (nb, C)
    if scale_is_e8m0:
        scales = c.e8m0_block_scale_k(absmax, data_p.emax)
    else:
        scales = c.generic_block_scale_k(absmax, data_p.max, scale_p, tscale)
    scaled = xb / (scales[:, None, :] * tscale)
    if stochastic:
        u = c.uniform_from_bits_k(rb).reshape(nb, block, ccols)
        codes = c.quantize_sr_k(scaled, data_p, u)
    else:
        codes = c.quantize_rtn_k(scaled, data_p)
    return (codes * scales[:, None, :]).reshape(r, ccols)


def _fused_kernel(a_ref, b_ref, arb_ref, brb_ref, tsa_ref, tsb_ref, o_ref, *,
                  block: int, data_p_a, scale_p_a, e8m0_a, sr_a: bool,
                  data_p_b, scale_p_b, e8m0_b, sr_b: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tsa = tsa_ref[0, 0]
    tsb = tsb_ref[0, 0]
    a = a_ref[...].astype(jnp.float32)            # (TM, TK) blocked along TK
    b = b_ref[...].astype(jnp.float32)            # (TK, TN) blocked along TK
    ad = _quant_tile_along_last(
        a, arb_ref[...], tsa, block=block, data_p=data_p_a, scale_p=scale_p_a,
        scale_is_e8m0=e8m0_a, stochastic=sr_a)
    bd = _quant_tile_along_first(
        b, brb_ref[...], tsb, block=block, data_p=data_p_b, scale_p=scale_p_b,
        scale_is_e8m0=e8m0_b, stochastic=sr_b)
    o_ref[...] += jnp.dot(ad, bd, preferred_element_type=jnp.float32) \
        * (tsa * tsb)


@functools.partial(jax.jit, static_argnames=(
    "spec_a", "spec_b", "interpret", "tm", "tn", "tk", "out_dtype"))
def fused_quant_matmul(a: jax.Array, b: jax.Array,
                       spec_a: BlockQuantSpec, spec_b: BlockQuantSpec, *,
                       a_rbits: Optional[jax.Array] = None,
                       b_rbits: Optional[jax.Array] = None,
                       out_dtype=jnp.float32, interpret: bool = False,
                       tm: int = 128, tn: int = 128,
                       tk: int = 512) -> jax.Array:
    """Quantize-a (blocks along axis1) x quantize-b (blocks along axis0) GEMM.

    The FQT hot path: one pallas_call per training GEMM, quantization fused.
    """
    if spec_a.block != spec_b.block:
        raise ValueError("operand block sizes must match")
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    B = spec_a.block
    if K % B:
        raise ValueError(f"K={K} not divisible by block={B}")

    from repro.kernels.ref import tensor_scale_ref
    tsa = tensor_scale_ref(a, spec_a).reshape(1, 1)
    tsb = tensor_scale_ref(b, spec_b).reshape(1, 1)

    dummy = jnp.zeros((1, 1), jnp.uint32)
    if not spec_a.stochastic:
        a_rbits = dummy
    if not spec_b.stochastic:
        b_rbits = dummy
    if spec_a.stochastic and (a_rbits is None or a_rbits.shape != a.shape):
        raise ValueError("spec_a stochastic requires a_rbits of a.shape")
    if spec_b.stochastic and (b_rbits is None or b_rbits.shape != b.shape):
        raise ValueError("spec_b stochastic requires b_rbits of b.shape")

    TM, TN = _pick_tile(M, tm), _pick_tile(N, tn)
    TK = _pick_tile(K, tk, B)
    grid = (M // TM, N // TN, K // TK)

    def _rb_spec(stoch, shape_map):
        if stoch:
            return pl.BlockSpec(*shape_map)
        return pl.BlockSpec((1, 1), lambda i, j, k: (0, 0))

    kernel = functools.partial(
        _fused_kernel, block=B,
        data_p_a=c.FmtParams.of(spec_a.data),
        scale_p_a=c.FmtParams.of(spec_a.scale),
        e8m0_a=(spec_a.scale_fmt == "e8m0"), sr_a=spec_a.stochastic,
        data_p_b=c.FmtParams.of(spec_b.data),
        scale_p_b=c.FmtParams.of(spec_b.scale),
        e8m0_b=(spec_b.scale_fmt == "e8m0"), sr_b=spec_b.stochastic)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TM, TK), lambda i, j, k: (i, k)),
            pl.BlockSpec((TK, TN), lambda i, j, k: (k, j)),
            _rb_spec(spec_a.stochastic, ((TM, TK), lambda i, j, k: (i, k))),
            _rb_spec(spec_b.stochastic, ((TK, TN), lambda i, j, k: (k, j))),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, b, a_rbits, b_rbits, tsa, tsb)
    return out.astype(out_dtype)


# ---- packed weights: quantize-a on the fly x unpack-dequant-b ----------------


def _packed_kernel(a_ref, bp_ref, bs_ref, arb_ref, tsa_ref, tsb_ref, o_ref, *,
                   block: int, block_b: int, data_p_a, scale_p_a, e8m0_a,
                   sr_a: bool):
    """A tile is quantized in VREGs exactly as in ``_fused_kernel``; the B
    tile arrives as nibble-packed E2M1 codes (half the bytes of an int8
    operand, 1/4 of bf16) + float8 block scales, and is unpacked/dequantized
    in VREGs — the decode-path weight stream out of HBM is ~4x smaller."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tsa = tsa_ref[0, 0]
    tsb = tsb_ref[0, 0]
    a = a_ref[...].astype(jnp.float32)            # (TM, TK) blocked along TK
    ad = _quant_tile_along_last(
        a, arb_ref[...], tsa, block=block, data_p=data_p_a,
        scale_p=scale_p_a, scale_is_e8m0=e8m0_a, stochastic=sr_a)
    bcodes = c.unpack_e2m1_k(bp_ref[...])         # (TK, TN) f32 grid values
    bsc = bs_ref[...].astype(jnp.float32)         # (TK//block_b, TN)
    tk, tn = bcodes.shape
    nb = tk // block_b
    bd = (bcodes.reshape(nb, block_b, tn) * bsc[:, None, :]).reshape(tk, tn)
    o_ref[...] += jnp.dot(ad, bd, preferred_element_type=jnp.float32) \
        * (tsa * tsb)


@functools.partial(jax.jit, static_argnames=(
    "spec_a", "block_b", "interpret", "tm", "tn", "tk", "out_dtype"))
def packed_block_matmul(a: jax.Array, b_packed: jax.Array,
                        b_scales: jax.Array, b_tscale: jax.Array,
                        spec_a: BlockQuantSpec, *, block_b: int = 16,
                        a_rbits: Optional[jax.Array] = None,
                        out_dtype=jnp.float32, interpret: bool = False,
                        tm: int = 128, tn: int = 256,
                        tk: int = 512) -> jax.Array:
    """Quantize-a x packed-b GEMM: the quantize-once serving hot path.

    ``b_packed``: (K, N//2) uint8 nibble pairs (pack_e2m1 layout, packed
    along N); ``b_scales``: (K//block_b, N) block scales (float8/bf16/f32);
    ``b_tscale``: scalar pow2 tensor scale.  A is quantized on the fly with
    ``spec_a`` (blocks along K), matching ``fused_quant_matmul``'s A side.

    Default TN=256 keeps the packed tile's last dim at 128 lanes on TPU;
    on the CPU backend the kernel runs in interpret mode like the others.
    """
    M, K = a.shape
    K2, halfN = b_packed.shape
    N = halfN * 2
    assert K == K2, (a.shape, b_packed.shape)
    B = spec_a.block
    if K % B or K % block_b:
        raise ValueError(f"K={K} not divisible by blocks {B}/{block_b}")

    from repro.kernels.ref import tensor_scale_ref
    tsa = tensor_scale_ref(a, spec_a).reshape(1, 1)
    tsb = jnp.asarray(b_tscale, jnp.float32).reshape(1, 1)

    dummy = jnp.zeros((1, 1), jnp.uint32)
    if not spec_a.stochastic:
        a_rbits = dummy
    elif a_rbits is None or a_rbits.shape != a.shape:
        raise ValueError("spec_a stochastic requires a_rbits of a.shape")

    TM = _pick_tile(M, tm)
    TN = _pick_tile(N, tn, 2)
    TK = _pick_tile(K, tk, max(B, block_b))
    grid = (M // TM, N // TN, K // TK)

    kernel = functools.partial(
        _packed_kernel, block=B, block_b=block_b,
        data_p_a=c.FmtParams.of(spec_a.data),
        scale_p_a=c.FmtParams.of(spec_a.scale),
        e8m0_a=(spec_a.scale_fmt == "e8m0"), sr_a=spec_a.stochastic)

    rb_spec = (pl.BlockSpec((TM, TK), lambda i, j, k: (i, k))
               if spec_a.stochastic
               else pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TM, TK), lambda i, j, k: (i, k)),
            pl.BlockSpec((TK, TN // 2), lambda i, j, k: (k, j)),
            pl.BlockSpec((TK // block_b, TN), lambda i, j, k: (k, j)),
            rb_spec,
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TM, TN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, b_packed, b_scales, a_rbits, tsa, tsb)
    return out.astype(out_dtype)


# ---- tensor-parallel form (mesh-native serving) -------------------------------
#
# The explicit Megatron decomposition of the packed FQT matmul, written as a
# shard_map over the serving mesh (distributed/compat.py shim, so it runs on
# the full supported JAX range and on CPU host-platform device counts):
#
#   column-parallel: W sharded on N (output features) — each device runs a
#     local packed GEMM on its own nibble-code / block-scale shard; NO
#     collective (the output stays sharded on N, which is exactly what the
#     next row-parallel GEMM wants).
#   row-parallel: X and W sharded on K (contraction) — local packed GEMM,
#     then a SINGLE psum of the partial products.
#
# With an FSDP-style ``gather_axis``, the weight is additionally sharded
# along K over that axis and the body first all-gathers the PACKED wire
# format (uint8 nibbles + f8 scales, ~4.5 bits/param — see
# distributed/compression.allgather_packed) instead of gathered bf16.
#
# This is the collective form the GSPMD engine path lowers to when packed
# leaves carry ``spec_for_packed`` partition specs; it exists explicitly so
# the decomposition is testable device-count-by-device-count (and is the
# shape a future Pallas ring-collective kernel would fuse into).


def tp_fp4_matmul(x, w, *, cfg, mesh, seed=None, parallel: str = "column",
                  axis: str = "model", gather_axis: Optional[str] = None):
    """Tensor-parallel packed FQT matmul: (..., K) @ packed (K, N) -> (..., N).

    ``w`` is a ``PackedQuantizedTensor`` (blocking axis -2).  The activation
    is quantized ONCE with global (single-device) semantics — ``cfg.fwd_a``
    amax over the full K — so column-parallel output is bit-identical to
    the 1-device packed forward; row-parallel differs only by psum
    reduction order.  Returns the full (global) product on every device
    per the out_specs (column: sharded on N; row: replicated).
    """
    import dataclasses

    from jax.sharding import PartitionSpec as P

    from repro.core import fqt
    from repro.core.quantize import PackedQuantizedTensor
    from repro.distributed.compat import shard_map

    if not isinstance(w, PackedQuantizedTensor) or w.ndim != 2:
        raise ValueError("tp_fp4_matmul needs a 2D PackedQuantizedTensor")
    if parallel not in ("column", "row"):
        raise ValueError(f"parallel={parallel!r}")
    K, N = w.shape
    if x.shape[-1] != K:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if seed is None:
        seed = jnp.zeros((), jnp.uint32)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, K)

    # activation quantization OUTSIDE the shard_map: global amax semantics
    fwd_a = fqt._if_divisible(cfg.fwd_a, K)
    qx = fqt._maybe_q(x2, fwd_a, axis=-1,
                      seed=jnp.asarray(seed, jnp.uint32), site=0)

    tp = axis
    k_axes = ((tp,) if parallel == "row" else ()) + \
        ((gather_axis,) if gather_axis else ())
    k_spec = None if not k_axes else \
        k_axes[0] if len(k_axes) == 1 else k_axes
    n_spec = tp if parallel == "column" else None
    # scale spec DERIVED from the code spec (same K/N axes) — the
    # congruence rule of distributed/sharding.spec_for_packed
    w_specs = dataclasses.replace(
        w, packed=P(k_spec, n_spec), scales=P(k_spec, n_spec), tscale=P())
    x_spec = P(None, tp if parallel == "row" else None)
    out_spec = P(None, tp) if parallel == "column" else P()

    def body(qx_l, w_l):
        if gather_axis:
            from repro.distributed.compression import allgather_packed
            w_l = allgather_packed(w_l, gather_axis, dim=0)
        y = jnp.matmul(qx_l, w_l.dequant(),
                       preferred_element_type=jnp.float32)
        if parallel == "row":
            y = jax.lax.psum(y, tp)
        return y.astype(x.dtype)

    y = shard_map(body, mesh=mesh, in_specs=(x_spec, w_specs),
                  out_specs=out_spec)(qx, w)
    return y.reshape(lead + (N,))
