"""jit'd public wrappers around the Pallas kernels.

``interpret`` mode is selected automatically: on the CPU backend the kernels
execute their bodies in interpret mode (bit-exact semantics, used by tests
and this container); on TPU they compile via Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantize import BlockQuantSpec, PackedQuantizedTensor
from repro.kernels import fp4_matmul as _mm
from repro.kernels import nvfp4_quant as _q


@functools.lru_cache(maxsize=None)
def _interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def block_quantize(x: jax.Array, spec: BlockQuantSpec, *,
                   rbits: Optional[jax.Array] = None,
                   interpret: Optional[bool] = None):
    """Standalone fused block-quantization kernel; returns (codes, scales)."""
    if interpret is None:
        interpret = _interpret_default()
    return _q.block_quantize_pallas(x, spec, rbits=rbits, interpret=interpret)


def block_matmul(a_codes, a_scales, b_codes, b_scales, tscale, *,
                 block: int = 16, interpret: Optional[bool] = None):
    """Block-scaled matmul on pre-quantized operands."""
    if interpret is None:
        interpret = _interpret_default()
    return _mm.block_matmul(a_codes, a_scales, b_codes, b_scales, tscale,
                            block=block, interpret=interpret)


def fused_quant_matmul(a, b, spec_a: BlockQuantSpec, spec_b: BlockQuantSpec, *,
                       a_rbits=None, b_rbits=None, out_dtype=jnp.float32,
                       interpret: Optional[bool] = None,
                       tm: int = 128, tn: int = 128, tk: int = 512):
    """The FQT hot path: quantize both operands on the fly + block-scaled MMA."""
    if interpret is None:
        interpret = _interpret_default()
    return _mm.fused_quant_matmul(a, b, spec_a, spec_b, a_rbits=a_rbits,
                                  b_rbits=b_rbits, out_dtype=out_dtype,
                                  interpret=interpret, tm=tm, tn=tn, tk=tk)


def packed_block_matmul(a, w: PackedQuantizedTensor, spec_a: BlockQuantSpec,
                        *, a_rbits=None, out_dtype=jnp.float32,
                        interpret: Optional[bool] = None,
                        tm: int = 128, tn: int = 256, tk: int = 512):
    """Quantize-a x packed-NVFP4-b GEMM (the quantize-once serving path).

    ``w`` holds nibble-packed codes along its last axis with blocks along
    axis -2 (the contraction axis), i.e. the layout ``pack_quantize``
    produces for a (K, N) weight.
    """
    if interpret is None:
        interpret = _interpret_default()
    if w.ndim != 2 or w.axis != -2:
        raise ValueError(f"packed weight must be (K, N) blocked along K, got "
                         f"shape {w.shape}, axis {w.axis}")
    return _mm.packed_block_matmul(a, w.packed, w.scales, w.tscale, spec_a,
                                   block_b=w.block, a_rbits=a_rbits,
                                   out_dtype=out_dtype, interpret=interpret,
                                   tm=tm, tn=tn, tk=tk)
