"""Pallas TPU kernel: fused NVFP4/MXFP4 block quantization.

One pass over the tensor: per 16-element block (along the last axis) compute
amax -> quantized shared scale (E4M3 RtN or E8M0 floor) -> E2M1 codes
(RtN or SR with explicit random bits).  HBM -> VMEM tiles via BlockSpec; the
MXU is not involved (pure VPU work), so tiles are sized for VMEM residency
and lane alignment (last dim multiples of 128, sublane multiples of 8).

On Blackwell this step is fused into the tensor-core data path; on TPU we
expose it standalone (for cache/checkpoint packing and for the unfused
matmul) and fused into the GEMM kernel (fp4_matmul.py) for the hot path —
see DESIGN.md §3 (hardware adaptation).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantize import BlockQuantSpec
from repro.kernels import common as c


def _quant_kernel(x_ref, rbits_ref, ts_ref, codes_ref, scales_ref, *,
                  block: int, data_p: c.FmtParams, scale_p: c.FmtParams,
                  scale_is_e8m0: bool, stochastic: bool):
    x = x_ref[...].astype(jnp.float32)                    # (TM, TK)
    tm, tk = x.shape
    nb = tk // block
    xb = x.reshape(tm, nb, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1)                # (TM, nb)
    tscale = ts_ref[0, 0]
    if scale_is_e8m0:
        scales = c.e8m0_block_scale_k(absmax, data_p.emax)
    else:
        scales = c.generic_block_scale_k(absmax, data_p.max, scale_p, tscale)
    scaled = xb / (scales[:, :, None] * tscale)
    if stochastic:
        u = c.uniform_from_bits_k(rbits_ref[...]).reshape(tm, nb, block)
        codes = c.quantize_sr_k(scaled, data_p, u)
    else:
        codes = c.quantize_rtn_k(scaled, data_p)
    codes_ref[...] = codes.reshape(tm, tk).astype(codes_ref.dtype)
    scales_ref[...] = scales.astype(scales_ref.dtype)


def _pick_tile(dim: int, pref: int, multiple: int = 1) -> int:
    """Largest divisor of dim that is <= pref and a multiple of `multiple`."""
    t = min(pref, dim)
    t -= t % multiple
    while t > multiple and dim % t != 0:
        t -= multiple
    if t <= 0 or dim % t != 0:
        t = dim
    return t


@functools.partial(jax.jit, static_argnames=("spec", "interpret"))
def block_quantize_pallas(
        x: jax.Array, spec: BlockQuantSpec, *,
        rbits: Optional[jax.Array] = None,
        tscale: Optional[jax.Array] = None,
        interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Quantize a 2D array along its last axis.  Returns (codes, scales).

    codes: x.shape, values on the E2M1 grid (times 1.0); scales:
    (M, K/block) float32.  Multiply codes*repeat(scales)*tscale to dequant.
    """
    if x.ndim != 2:
        raise ValueError(f"expected 2D input, got {x.shape}")
    M, K = x.shape
    B = spec.block
    if K % B:
        raise ValueError(f"K={K} not divisible by block={B}")
    if tscale is None:
        from repro.kernels.ref import tensor_scale_ref
        tscale = tensor_scale_ref(x, spec)
    tscale = jnp.asarray(tscale, jnp.float32).reshape(1, 1)
    if rbits is None:
        rbits = jnp.zeros((1, 1), jnp.uint32) if not spec.stochastic else None
    if spec.stochastic and (rbits is None or rbits.shape != x.shape):
        raise ValueError("SR requires rbits with the same shape as x")

    TM = _pick_tile(M, 256, 8 if M % 8 == 0 else 1)
    TK = _pick_tile(K, 2048, B)
    grid = (M // TM, K // TK)

    kernel = functools.partial(
        _quant_kernel, block=B,
        data_p=c.FmtParams.of(spec.data), scale_p=c.FmtParams.of(spec.scale),
        scale_is_e8m0=(spec.scale_fmt == "e8m0"), stochastic=spec.stochastic)

    rb_spec = (pl.BlockSpec((TM, TK), lambda i, j: (i, j))
               if spec.stochastic else pl.BlockSpec((1, 1), lambda i, j: (0, 0)))
    if not spec.stochastic:
        rbits = jnp.zeros((1, 1), jnp.uint32)

    codes, scales = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TM, TK), lambda i, j: (i, j)),
            rb_spec,
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TM, TK), lambda i, j: (i, j)),
            pl.BlockSpec((TM, TK // B), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, K), x.dtype),
            jax.ShapeDtypeStruct((M, K // B), jnp.float32),
        ],
        interpret=interpret,
    )(x, rbits, tscale)
    return codes, scales
