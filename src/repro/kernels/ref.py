"""Pure-jnp oracles for the Pallas kernels.

These mirror the kernels' semantics *exactly* (same scale rules, same
uniform-bits convention for SR), so kernel tests can assert bit-equality in
interpret mode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import formats
from repro.core.quantize import BlockQuantSpec, _tensor_scale


def tensor_scale_ref(x: jax.Array, spec: BlockQuantSpec) -> jax.Array:
    """Per-tensor pow2 scale (computed outside the kernels; cheap reduction)."""
    return _tensor_scale(jnp.max(jnp.abs(x.astype(jnp.float32))), spec)


def block_quant_ref(x: jax.Array, spec: BlockQuantSpec, *,
                    rbits: Optional[jax.Array] = None,
                    tscale: Optional[jax.Array] = None,
                    axis: int = -1) -> Tuple[jax.Array, jax.Array]:
    """Block quantization along ``axis``; returns (codes, scales).

    ``codes`` are dequantized-grid values (code * 1.0), i.e. E2M1 grid points;
    reconstruct with codes * repeat(scales, B, axis) * tscale.
    """
    axis = axis % x.ndim
    B = spec.block
    xf = x.astype(jnp.float32)
    if tscale is None:
        tscale = tensor_scale_ref(x, spec)
    shp = xf.shape
    nb = shp[axis] // B
    xb = jnp.moveaxis(xf, axis, -1).reshape(-1, nb, B)
    absmax = jnp.max(jnp.abs(xb), axis=-1)                      # (R, nb)
    if spec.scale_fmt == "e8m0":
        scales = formats.e8m0_floor(absmax) / (2.0 ** spec.data.emax)
        scales = jnp.where(absmax > 0, scales, 1.0)
    else:
        raw = absmax / (spec.data.max * tscale)
        scales = formats.quantize_rtn(raw, spec.scale)
        scales = jnp.where(scales > 0, scales, 1.0)
    scaled = xb / (scales[..., None] * tscale)
    if spec.stochastic:
        if rbits is None:
            raise ValueError("SR requires rbits")
        rb = jnp.moveaxis(rbits, axis, -1).reshape(-1, nb, B)
        u = formats.uniform_from_bits(rb)
        codes = formats.quantize_sr_with_u(scaled, spec.data, u)
    else:
        codes = formats.quantize_rtn(scaled, spec.data)
    # restore layouts
    def _restore(a, last):
        a = a.reshape(tuple(jnp.moveaxis(xf, axis, -1).shape[:-1]) + (last,))
        return jnp.moveaxis(a, -1, axis)
    codes = _restore(codes.reshape(-1, nb * B), nb * B).astype(x.dtype)
    scales = _restore(scales, nb).astype(jnp.float32)
    return codes, scales


def block_matmul_ref(a_codes: jax.Array, a_scales: jax.Array,
                     b_codes: jax.Array, b_scales: jax.Array,
                     tscale: jax.Array, block: int,
                     out_dtype=jnp.float32) -> jax.Array:
    """(M,K) x (K,N) block-scaled matmul, fp32 accumulation.

    a blocked along K (axis 1, scales (M, K/B)); b blocked along K (axis 0,
    scales (K/B, N)); ``tscale`` = tscale_a * tscale_b applied at the end.
    """
    ad = a_codes.astype(jnp.float32) * jnp.repeat(a_scales, block, axis=1)
    bd = b_codes.astype(jnp.float32) * jnp.repeat(b_scales, block, axis=0)
    out = jnp.matmul(ad, bd, preferred_element_type=jnp.float32) * tscale
    return out.astype(out_dtype)


def packed_attention_ref(q: jax.Array, k_codes: jax.Array,
                         k_scales: jax.Array, v_codes: jax.Array,
                         v_scales: jax.Array, *, fmt: str = "nvfp4",
                         block: int = 16, causal: bool = True,
                         window: Optional[int] = None,
                         kv_len: Optional[int] = None,
                         q_offset: int = 0) -> jax.Array:
    """Oracle for ``flash_attn.flash_attention_packed`` and the layers.py
    packed decode read: dequantize the WHOLE cache, then dense softmax.

    Mirrors the fused paths' semantics exactly (same RtN storage grid, same
    masks); the fused implementations differ only in never materializing
    the dequantized cache.
    """
    from repro.core.quantize import kv_dequant
    from repro.models.layers import attention_core

    B, Sq, H, D = q.shape
    Sk = k_codes.shape[1]
    k = kv_dequant(k_codes, k_scales, fmt, block, jnp.float32)
    v = kv_dequant(v_codes, v_scales, fmt, block, jnp.float32)
    qpos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    kpos = jnp.arange(Sk, dtype=jnp.int32)
    kl = None if kv_len is None else jnp.asarray(kv_len, jnp.int32)
    return attention_core(q.astype(jnp.float32), k, v, qpos=qpos, kpos=kpos,
                          causal=causal, window=window, chunk=2 ** 30,
                          kv_len=kl).astype(q.dtype)


def paged_attention_ref(q: jax.Array, k_codes: jax.Array,
                        k_scales: jax.Array, v_codes: jax.Array,
                        v_scales: jax.Array, page_table, lengths,
                        q_offsets, *, fmt: str = "nvfp4", block: int = 16,
                        causal: bool = True,
                        window: Optional[int] = None) -> jax.Array:
    """Oracle for ``flash_attn.flash_attention_paged`` and the layers.py
    paged decode read: gather every slot's logical buffer through the page
    table, dequantize the WHOLE cache, then run dense softmax PER SLOT
    with that slot's own (q_offset, kv_len).

    q: (B, Sq, H, D); codes/scales: the page-POOL layout (P, page, KVH, ·);
    page_table: (B, n_pages); lengths/q_offsets: (B,).  Mirrors the fused
    paths' semantics exactly (same storage grid, same rolling-slot
    position rule); the fused implementations differ only in never
    materializing the gathered/dequantized cache.
    """
    from repro.models.layers import (_kv_dequant_any, attention_core,
                                     swa_kpos)

    B, Sq, H, D = q.shape
    psz = k_codes.shape[1]
    pt = jnp.asarray(page_table, jnp.int32)
    buf = pt.shape[1] * psz

    def gather(pool):
        a = pool[pt]                           # (B, n_pages, page, KVH, ·)
        return a.reshape((B, buf) + pool.shape[2:])

    k = _kv_dequant_any(gather(k_codes), gather(k_scales), fmt, block,
                        jnp.float32)
    v = _kv_dequant_any(gather(v_codes), gather(v_scales), fmt, block,
                        jnp.float32)
    lengths = jnp.asarray(lengths, jnp.int32)
    q_offsets = jnp.asarray(q_offsets, jnp.int32)
    outs = []
    for i in range(B):                         # per-slot dense attention
        qpos = q_offsets[i] + jnp.arange(Sq, dtype=jnp.int32)
        if window is None:
            kpos = jnp.arange(buf, dtype=jnp.int32)
        else:
            kpos = swa_kpos((q_offsets[i] + Sq)[None], buf)[0]
            kpos = jnp.where(kpos >= 0, kpos, jnp.int32(2 ** 30))
        kv_len = jnp.minimum(lengths[i], buf)
        outs.append(attention_core(
            q[i:i + 1].astype(jnp.float32), k[i:i + 1], v[i:i + 1],
            qpos=qpos, kpos=kpos, causal=causal, window=window,
            chunk=2 ** 30, kv_len=kv_len))
    return jnp.concatenate(outs, axis=0).astype(q.dtype)


def fused_quant_matmul_ref(a: jax.Array, b: jax.Array, spec_a: BlockQuantSpec,
                           spec_b: BlockQuantSpec, *,
                           a_rbits: Optional[jax.Array] = None,
                           b_rbits: Optional[jax.Array] = None,
                           out_dtype=jnp.float32) -> jax.Array:
    """Quantize a along axis 1 and b along axis 0, then block-scaled matmul."""
    tsa = tensor_scale_ref(a, spec_a)
    tsb = tensor_scale_ref(b, spec_b)
    ac, asc = block_quant_ref(a, spec_a, rbits=a_rbits, tscale=tsa, axis=1)
    bc, bsc = block_quant_ref(b, spec_b, rbits=b_rbits, tscale=tsb, axis=0)
    return block_matmul_ref(ac, asc, bc, bsc, tsa * tsb, spec_a.block,
                            out_dtype)
