"""Arithmetic-only minifloat quantization helpers usable *inside* Pallas
kernel bodies (no frexp, no exotic dtypes — just bitcasts, shifts, round,
floor; all supported by Mosaic on TPU and by interpret mode on CPU).

Bit-exact against repro.core.formats (tested in tests/test_kernels.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import FloatFormat


class FmtParams(NamedTuple):
    """Static per-format constants passed into kernels."""
    man_bits: int
    emin: int           # smallest normal exponent
    emax: int           # largest normal exponent
    max: float          # largest finite

    @classmethod
    def of(cls, fmt: FloatFormat) -> "FmtParams":
        return cls(fmt.man_bits, fmt.emin, fmt.emax, fmt.max)


def _ulp_from_bits(a: jax.Array, p: FmtParams) -> jax.Array:
    """Grid spacing at |a| (a >= 0, float32), via exponent-field extraction.

    ulp = 2^(clip(floor(log2 a), emin, emax) - man_bits); matches
    formats._ulp bit-for-bit (incl. binade boundaries: 2^k has exponent k).
    """
    bits = jax.lax.bitcast_convert_type(a, jnp.uint32)
    e = ((bits >> 23) & 0xFF).astype(jnp.int32) - 127   # floor(log2 a)
    e = jnp.clip(e, p.emin, p.emax)
    ulp_bits = ((e - p.man_bits + 127) << 23).astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(ulp_bits, jnp.float32)


def quantize_rtn_k(x: jax.Array, p: FmtParams) -> jax.Array:
    """Round-to-nearest-even onto the grid (float32 in/out), saturating."""
    s = jnp.sign(x)
    a = jnp.minimum(jnp.abs(x), p.max)
    ulp = _ulp_from_bits(a, p)
    q = jnp.round(a / ulp) * ulp
    return s * jnp.minimum(q, p.max)


def quantize_sr_k(x: jax.Array, p: FmtParams, u: jax.Array) -> jax.Array:
    """Stochastic rounding with uniforms u in [0,1):  floor(|x|/ulp + u)*ulp."""
    s = jnp.sign(x)
    a = jnp.minimum(jnp.abs(x), p.max)
    ulp = _ulp_from_bits(a, p)
    q = jnp.floor(a / ulp + u) * ulp
    return s * jnp.minimum(q, p.max)


def uniform_from_bits_k(rbits: jax.Array) -> jax.Array:
    """uint32 -> [0,1) float32; same convention as formats.uniform_from_bits."""
    return (rbits >> 8).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def _decode_e2m1_nibble_k(nib: jax.Array) -> jax.Array:
    """4-bit E2M1 code (s eem) -> float32 grid value, arithmetic only.

    Normals (e>0): value = (1 + m/2) * 2^(e-1), built by assembling the f32
    bit pattern directly (exponent field e-1+127, mantissa bit 22 = m) —
    shifts + bitcast, the same toolbox as the rest of this module.
    Subnormals (e==0): value = m * 0.5.
    """
    n = nib.astype(jnp.uint32)
    sign = jnp.where((n & 0x8) != 0, jnp.float32(-1.0), jnp.float32(1.0))
    e = (n >> 1) & 0x3
    m = n & 0x1
    vbits = (((e + jnp.uint32(126)) << 23) | (m << 22)).astype(jnp.uint32)
    normal = jax.lax.bitcast_convert_type(vbits, jnp.float32)
    mag = jnp.where(e == 0, m.astype(jnp.float32) * 0.5, normal)
    return sign * mag


def unpack_e2m1_k(packed: jax.Array) -> jax.Array:
    """uint8 nibble pairs -> f32 E2M1 grid values, interleaved on the last
    axis (inverse of quantize.pack_e2m1); usable inside Pallas kernels."""
    lo = _decode_e2m1_nibble_k(packed & 0xF)
    hi = _decode_e2m1_nibble_k(packed >> 4)
    stacked = jnp.stack([lo, hi], axis=-1)
    return stacked.reshape(packed.shape[:-1] + (packed.shape[-1] * 2,))


def e8m0_block_scale_k(absmax: jax.Array, data_emax: int) -> jax.Array:
    """OCP MX rule: scale = 2^(floor(log2 amax) - emax_elem); 1.0 for amax=0."""
    bits = jax.lax.bitcast_convert_type(absmax, jnp.uint32)
    e = ((bits >> 23) & 0xFF).astype(jnp.int32) - 127
    e = jnp.clip(e, -127, 127)
    pbits = ((e + 127) << 23).astype(jnp.uint32)
    p2 = jax.lax.bitcast_convert_type(pbits, jnp.float32)   # 2^floor(log2 amax)
    scale = p2 / jnp.float32(2.0 ** data_emax)              # exact pow2 division
    return jnp.where(absmax > 0, scale, 1.0)


def generic_block_scale_k(absmax: jax.Array, data_max: float,
                          scale_p: FmtParams, tscale: jax.Array) -> jax.Array:
    """RtN block scale: Q_rtn(amax / (data_max * tscale)); 1.0 for zero."""
    raw = absmax / (data_max * tscale)
    scale = quantize_rtn_k(raw, scale_p)
    return jnp.where(scale > 0, scale, 1.0)
