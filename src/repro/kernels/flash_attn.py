"""Pallas TPU kernel: fused flash-attention forward (serving hot path).

The §Perf loop showed the residual memory term of attention-heavy cells is
the XLA-materialized f32 score chains (dot → where → exp → ... each a
separate HBM round trip at 4 bytes/element).  This kernel keeps the whole
(block_q × block_kv) score tile in VMEM/VREGs: one HBM read of q/k/v and
one write of the output — the Blackwell-kernel dataflow mapped to the TPU
memory hierarchy (HBM→VMEM tiles, MXU for qkᵀ and pv, VPU for the running
softmax).

Grid: (B, H, nq, nk) with the kv axis innermost; the output block
(block_q, D) is revisited across kv steps, the running (m, l) statistics
live in VMEM scratch.  GQA is folded into the k/v BlockSpec index maps
(head h reads kv-head h // group).  Causal + sliding-window masks are
applied from block-local iotas, and fully-masked kv blocks are skipped via
``pl.when`` (the compute saving the XLA-level flash cannot express).

Backward stays on the custom_vjp jnp path (models/layers.py) — training
wants the FQT GEMM kernels' fusion budget; this kernel serves the
prefill/decode forward.  Oracle: ``ref.flash_attention_ref`` (dense
softmax); validated in interpret mode over shape/dtype/mask sweeps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_kv: int, causal: bool,
                  window: Optional[int], seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this tile's rows/cols
    q0 = qi * block_q
    k0 = ki * block_kv
    qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)

    # skip fully-masked kv blocks (beyond causal frontier / before window)
    run = True
    if causal:
        run = jnp.asarray(k0 <= q0 + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, jnp.asarray(k0 + block_kv - 1 > q0 - window))

    @pl.when(run)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # (bq, bk)
        s = s * (q.shape[-1] ** -0.5)
        mask = kpos < seq_k                             # pad guard
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        m_scr[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v_ref.dtype).astype(jnp.float32), v,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]
                             ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_kv",
                              "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Fused attention forward.  q: (B, Sq, H, D); k/v: (B, Sk, KVH, D).

    H must be a multiple of KVH (GQA); Sq/Sk must divide by the block
    sizes (configs are powers of two; callers pad otherwise).
    """
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    if H % KVH:
        raise ValueError(f"GQA: H={H} not a multiple of KVH={KVH}")
    G = H // KVH
    bq = min(block_q, Sq)
    bkv = min(block_kv, Sk)
    if Sq % bq or Sk % bkv:
        raise ValueError(f"seq ({Sq},{Sk}) not divisible by blocks "
                         f"({bq},{bkv})")
    grid = (B, H, Sq // bq, Sk // bkv)

    kernel = functools.partial(
        _flash_kernel, block_q=bq, block_kv=bkv, causal=causal,
        window=window, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bkv, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bkv, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m: running row max
            pltpu.VMEM((bq,), jnp.float32),       # l: running denominator
            pltpu.VMEM((bq, D), jnp.float32),     # acc: fp32 output tile
        ],
        interpret=interpret,
    )(q, k, v)
