"""Pallas TPU kernel: fused flash-attention forward (serving hot path).

The §Perf loop showed the residual memory term of attention-heavy cells is
the XLA-materialized f32 score chains (dot → where → exp → ... each a
separate HBM round trip at 4 bytes/element).  This kernel keeps the whole
(block_q × block_kv) score tile in VMEM/VREGs: one HBM read of q/k/v and
one write of the output — the Blackwell-kernel dataflow mapped to the TPU
memory hierarchy (HBM→VMEM tiles, MXU for qkᵀ and pv, VPU for the running
softmax).

Grid: (B, H, nq, nk) with the kv axis innermost; the output block
(block_q, D) is revisited across kv steps, the running (m, l) statistics
live in VMEM scratch.  GQA is folded into the k/v BlockSpec index maps
(head h reads kv-head h // group).  Causal + sliding-window masks are
applied from block-local iotas, and fully-masked kv blocks are skipped via
``pl.when`` (the compute saving the XLA-level flash cannot express).

Backward stays on the custom_vjp jnp path (models/layers.py) — training
wants the FQT GEMM kernels' fusion budget; this kernel serves the
prefill/decode forward.  Oracle: ``ref.flash_attention_ref`` (dense
softmax); validated in interpret mode over shape/dtype/mask sweeps.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _tile_run_predicate(q0, block_q: int, k0, block_kv: int, causal: bool,
                        window: Optional[int]):
    """Whether this kv tile can contribute at all (causal/window skip).
    q0/k0: absolute position of the tile's first row/column."""
    run = True
    if causal:
        run = jnp.asarray(k0 <= q0 + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, jnp.asarray(k0 + block_kv - 1 > q0 - window))
    return run


def _tile_mask(qpos, kpos, valid, causal: bool, window: Optional[int]):
    """Combine the pad/validity guard with causal + sliding-window masks."""
    mask = valid
    if causal:
        mask = jnp.logical_and(mask, kpos <= qpos)
    if window is not None:
        mask = jnp.logical_and(mask, kpos > qpos - window)
    return mask


def _tile_softmax_update(q, k, v, mask, m_scr, l_scr, acc_scr, *,
                         v_store_dtype):
    """One (block_q x block_kv) score-tile update of the running softmax.

    q/k/v are f32 tiles already resident in VMEM/VREGs — for the packed
    cache variant they were dequantized in-register just before this call,
    so the bf16 cache never exists in HBM.
    """
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)             # (bq, bk)
    s = s * (q.shape[-1] ** -0.5)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v_store_dtype).astype(jnp.float32), v,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_kv: int, causal: bool,
                  window: Optional[int], seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this tile's rows/cols
    qpos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    kpos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)

    # skip fully-masked kv blocks (beyond causal frontier / before window)
    run = _tile_run_predicate(qi * block_q, block_q, ki * block_kv,
                              block_kv, causal, window)

    @pl.when(run)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        mask = _tile_mask(qpos, kpos, kpos < seq_k, causal, window)
        _tile_softmax_update(q, k, v, mask, m_scr, l_scr, acc_scr,
                             v_store_dtype=v_ref.dtype)

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]
                             ).astype(o_ref.dtype)


# ---- packed (block-quantized) KV cache variant --------------------------------


def _dequant_kv_tile(codes, scales, fmt: str, block: int) -> jax.Array:
    """Dequantize one (bkv, D)-logical K/V tile in VREGs.

    ``codes``: (bkv, D/2) uint8 nibble pairs (nvfp4) or (bkv, D) float8
    (fp8); ``scales``: (bkv, D/block).  The bf16 cache never exists in HBM —
    this runs after the tile load, before the score dot.  ``fmt="bf16"``
    (the paged escape hatch) passes the tile through unscaled.
    """
    from repro.kernels import common as c
    if fmt == "bf16":
        return codes.astype(jnp.float32)
    if fmt == "nvfp4":
        vals = c.unpack_e2m1_k(codes)                   # (bkv, D) f32 grid
    else:                                               # fp8
        vals = codes.astype(jnp.float32)
    bkv, D = vals.shape
    nb = D // block
    s = scales.astype(jnp.float32)                      # (bkv, nb)
    return (vals.reshape(bkv, nb, block) * s[:, :, None]).reshape(bkv, D)


def _flash_packed_kernel(q_ref, kc_ref, ks_ref, vc_ref, vs_ref, pos_ref,
                         o_ref, m_scr, l_scr, acc_scr, *, block_q: int,
                         block_kv: int, causal: bool, window: Optional[int],
                         fmt: str, block: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    # dynamic decode-state scalars (NOT compile-time constants: they advance
    # every decoded token, so baking them in would recompile per step)
    q_offset = pos_ref[0, 0]
    seq_k = pos_ref[0, 1]                               # valid kv slots

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    kpos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)

    run = _tile_run_predicate(q_offset + qi * block_q, block_q,
                              ki * block_kv, block_kv, causal, window)

    @pl.when(jnp.asarray(run))
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, D)
        k = _dequant_kv_tile(kc_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                             fmt, block)
        v = _dequant_kv_tile(vc_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                             fmt, block)
        # p stays f32 into the pv dot: v was dequantized to f32 in-register,
        # so there is no lower-precision operand to match (unlike the bf16
        # cache kernel, where p is cast down to the cache dtype)
        mask = _tile_mask(qpos, kpos, kpos < seq_k, causal, window)
        _tile_softmax_update(q, k, v, mask, m_scr, l_scr, acc_scr,
                             v_store_dtype=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]
                             ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("fmt", "block", "causal", "window",
                              "block_q", "block_kv", "interpret"))
def flash_attention_packed(q: jax.Array, k_codes: jax.Array,
                           k_scales: jax.Array, v_codes: jax.Array,
                           v_scales: jax.Array, *, fmt: str = "nvfp4",
                           block: int = 16, causal: bool = True,
                           window: Optional[int] = None,
                           kv_len=None, q_offset=0,
                           block_q: int = 128, block_kv: int = 128,
                           interpret: bool = False) -> jax.Array:
    """Fused attention over a BLOCK-QUANTIZED KV cache.

    q: (B, Sq, H, D) bf16/f32; k/v codes+scales: the ``PackedKVCache``
    layout — nvfp4: (B, Sk, KVH, D/2) uint8 + (B, Sk, KVH, D/block)
    float8_e4m3fn scales; fp8: (B, Sk, KVH, D) float8 codes + bf16 scales.
    K/V tiles stream out of HBM at their packed width and are dequantized
    in VREGs right before the qk^T / pv dots, so decode attention pays
    0.5625 (nvfp4) or 1.125 (fp8) bytes/element of cache traffic instead
    of 2.

    ``q_offset``: absolute position of q row 0 (decode reads: cache length
    - Sq); ``kv_len``: valid-slot count (defaults to Sk).  Both are
    DYNAMIC scalars (int or traced) fed to the kernel as a (1, 2) operand
    — they advance every decoded token, so one compiled program covers the
    whole decode loop.  Oracle: ``ref.packed_attention_ref``
    (dequantize-then-dense-softmax).
    """
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k_codes.shape
    if fmt not in ("nvfp4", "fp8"):
        raise ValueError(f"unknown packed KV format {fmt!r}")
    Dc = D // 2 if fmt == "nvfp4" else D
    if k_codes.shape[-1] != Dc or D % block:
        raise ValueError(f"bad packed layout: codes last dim "
                         f"{k_codes.shape[-1]}, head dim {D}, block {block}")
    if H % KVH:
        raise ValueError(f"GQA: H={H} not a multiple of KVH={KVH}")
    G = H // KVH
    bq = min(block_q, Sq)
    bkv = min(block_kv, Sk)
    if Sq % bq or Sk % bkv:
        raise ValueError(f"seq ({Sq},{Sk}) not divisible by blocks "
                         f"({bq},{bkv})")
    nb = D // block
    grid = (B, H, Sq // bq, Sk // bkv)

    kernel = functools.partial(
        _flash_packed_kernel, block_q=bq, block_kv=bkv, causal=causal,
        window=window, fmt=fmt, block=block)
    pos = jnp.stack([jnp.asarray(q_offset, jnp.int32),
                     jnp.asarray(Sk if kv_len is None else kv_len,
                                 jnp.int32)]).reshape(1, 2)

    kv_spec = pl.BlockSpec((1, bkv, 1, Dc),
                           lambda b, h, qi, ki, G=G: (b, ki, h // G, 0))
    sc_spec = pl.BlockSpec((1, bkv, 1, nb),
                           lambda b, h, qi, ki, G=G: (b, ki, h // G, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            kv_spec, sc_spec, kv_spec, sc_spec,
            pl.BlockSpec((1, 2), lambda b, h, qi, ki: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m: running row max
            pltpu.VMEM((bq,), jnp.float32),       # l: running denominator
            pltpu.VMEM((bq, D), jnp.float32),     # acc: fp32 output tile
        ],
        interpret=interpret,
    )(q, k_codes, k_scales, v_codes, v_scales, pos)


# ---- paged (continuous-batching) KV cache variant -----------------------------


def _flash_paged_kernel(pt_ref, pos_ref, q_ref, kc_ref, ks_ref, vc_ref,
                        vs_ref, o_ref, m_scr, l_scr, acc_scr, *,
                        block_q: int, page_size: int, buf: int, seq_q: int,
                        causal: bool, window: Optional[int], fmt: str,
                        block: int):
    """Grid (B, H, nq, n_pages): one K/V PAGE per kv step, fetched through
    the page table (the scalar-prefetch ref drives the BlockSpec index
    maps, so each step DMAs exactly the physical page this slot's logical
    page ``ki`` lives in).  Per-slot (q_offset, kv_len) come from the
    second scalar-prefetch operand — vector state, one row per slot."""
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    q_offset = pos_ref[b, 0]
    kv_len = pos_ref[b, 1]                              # min(length, buf)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, page_size), 0)
    # logical slot j of this tile's columns -> absolute position held by it
    j = ki * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, page_size), 1)
    if window is None:
        kpos = j                                        # linear: pos == slot
    else:
        # rolling (SWA): slot j holds the latest token with pos % buf == j
        last = q_offset + seq_q - 1
        kpos = last - ((last % buf - j) % buf)
    valid = jnp.logical_and(j < kv_len, kpos >= 0)

    # skip pages that are entirely beyond the valid slot count, and (for
    # linear caches, where kpos is monotone in j) beyond the causal
    # frontier / before the window
    run = ki * page_size < kv_len
    if window is None:
        run = jnp.logical_and(
            run, _tile_run_predicate(q_offset + qi * block_q, block_q,
                                     ki * page_size, page_size, causal,
                                     None))

    @pl.when(run)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # (bq, D)
        k = _dequant_kv_tile(kc_ref[0, :, 0, :], ks_ref[0, :, 0, :],
                             fmt, block)
        v = _dequant_kv_tile(vc_ref[0, :, 0, :], vs_ref[0, :, 0, :],
                             fmt, block)
        mask = _tile_mask(qpos, kpos, valid, causal, window)
        _tile_softmax_update(q, k, v, mask, m_scr, l_scr, acc_scr,
                             v_store_dtype=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]
                             ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("fmt", "block", "causal", "window",
                              "block_q", "interpret"))
def flash_attention_paged(q: jax.Array, k_codes: jax.Array,
                          k_scales: jax.Array, v_codes: jax.Array,
                          v_scales: jax.Array, page_table: jax.Array,
                          lengths: jax.Array, q_offsets: jax.Array, *,
                          fmt: str = "nvfp4", block: int = 16,
                          causal: bool = True,
                          window: Optional[int] = None,
                          block_q: int = 128,
                          interpret: bool = False) -> jax.Array:
    """Fused attention over a PAGED block-quantized KV cache (continuous
    batching): K/V tiles are gathered one physical page at a time through
    ``page_table`` and every slot carries its own (q_offset, kv_len) —
    the per-slot vector operands that replace the shared decode scalars.

    q: (B, Sq, H, D); codes/scales: the ``PagedKVCache`` page POOL layout —
    (P, page, KVH, D/2) uint8 + (P, page, KVH, D/block) f8 scales (nvfp4),
    (P, page, KVH, D) f8 codes + bf16 scales (fp8), or (P, page, KVH, D)
    bf16 codes (the escape hatch, scales ignored).  ``page_table``:
    (B, n_pages) int32 physical page per logical page; ``lengths``: (B,)
    valid tokens per slot; ``q_offsets``: (B,) absolute position of each
    slot's q row 0.  The kv block size IS the page size (one page per
    grid step; hardware wants >= 128-token pages — ROADMAP).  Oracle:
    ``ref.paged_attention_ref``.
    """
    B, Sq, H, D = q.shape
    P, psz, KVH, Dc = k_codes.shape
    if fmt not in ("nvfp4", "fp8", "bf16"):
        raise ValueError(f"unknown paged KV format {fmt!r}")
    want_dc = D // 2 if fmt == "nvfp4" else D
    if Dc != want_dc or D % block:
        raise ValueError(f"bad paged layout: codes last dim {Dc}, head dim "
                         f"{D}, block {block}")
    if H % KVH:
        raise ValueError(f"GQA: H={H} not a multiple of KVH={KVH}")
    G = H // KVH
    bq = min(block_q, Sq)
    if Sq % bq:
        raise ValueError(f"seq {Sq} not divisible by block_q {bq}")
    n_pages = page_table.shape[1]
    buf = n_pages * psz
    nb = k_scales.shape[-1]
    grid = (B, H, Sq // bq, n_pages)

    kernel = functools.partial(
        _flash_paged_kernel, block_q=bq, page_size=psz, buf=buf, seq_q=Sq,
        causal=causal, window=window, fmt=fmt, block=block)
    pos = jnp.stack([jnp.asarray(q_offsets, jnp.int32),
                     jnp.asarray(lengths, jnp.int32)], axis=1)   # (B, 2)

    kv_spec = pl.BlockSpec(
        (1, psz, 1, Dc),
        lambda b, h, qi, ki, pt, pos_: (pt[b, ki], 0, h // G, 0))
    sc_spec = pl.BlockSpec(
        (1, psz, 1, nb),
        lambda b, h, qi, ki, pt, pos_: (pt[b, ki], 0, h // G, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # page_table, (q_offset, kv_len)
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D),
                         lambda b, h, qi, ki, pt, pos_: (b, qi, h, 0)),
            kv_spec, sc_spec, kv_spec, sc_spec,
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, qi, ki, pt, pos_: (b, qi, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m: running row max
            pltpu.VMEM((bq,), jnp.float32),       # l: running denominator
            pltpu.VMEM((bq, D), jnp.float32),     # acc: fp32 output tile
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(page_table, jnp.int32), pos,
      q, k_codes, k_scales, v_codes, v_scales)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_kv",
                              "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Fused attention forward.  q: (B, Sq, H, D); k/v: (B, Sk, KVH, D).

    H must be a multiple of KVH (GQA); Sq/Sk must divide by the block
    sizes (configs are powers of two; callers pad otherwise).
    """
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    if H % KVH:
        raise ValueError(f"GQA: H={H} not a multiple of KVH={KVH}")
    G = H // KVH
    bq = min(block_q, Sq)
    bkv = min(block_kv, Sk)
    if Sq % bq or Sk % bkv:
        raise ValueError(f"seq ({Sq},{Sk}) not divisible by blocks "
                         f"({bq},{bkv})")
    grid = (B, H, Sq // bq, Sk // bkv)

    kernel = functools.partial(
        _flash_kernel, block_q=bq, block_kv=bkv, causal=causal,
        window=window, seq_k=Sk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bkv, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bkv, 1, D),
                         lambda b, h, qi, ki, G=G: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m: running row max
            pltpu.VMEM((bq,), jnp.float32),       # l: running denominator
            pltpu.VMEM((bq, D), jnp.float32),     # acc: fp32 output tile
        ],
        interpret=interpret,
    )(q, k, v)
