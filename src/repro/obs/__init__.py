"""Observability layer: simulated-clock tracing + telemetry (jax-free)."""
from repro.obs.trace import (Counters, NULL_TRACER, NullTracer,
                             REQUIRED_EVENT_KEYS, Tracer, load_trace,
                             validate_events)

__all__ = ["Counters", "NULL_TRACER", "NullTracer", "REQUIRED_EVENT_KEYS",
           "Tracer", "load_trace", "validate_events"]
