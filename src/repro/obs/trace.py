"""fp4trace — simulated-clock tracing and telemetry (jax-free).

One ``Tracer`` records three kinds of telemetry, all host-side:

  * **spans** — ``begin(track, name)`` / ``end(track, name)`` pairs, e.g.
    one span per serve request from ``submit`` to done/cancelled, one span
    per engine tick;
  * **counters** — monotonically accumulated totals (``counter(name, d)``):
    page allocations, prefix-cache hits, √3-threshold crossings;
  * **gauges** — instantaneous values (``gauge(name, v)``): queue depth,
    gradient-to-noise ratio per layer.

Timestamps are SIMULATED clock readings — scheduler ticks on the serve
side, optimizer steps on the train side — driven by ``set_time``.  Wall
clock is an optional per-event annotation (``wall=True``) that never
participates in assertions, so traces stay deterministic and replayable.

The exporter writes Chrome trace-event JSON (the ``traceEvents`` array
form), loadable in Perfetto / ``chrome://tracing``: spans become "B"/"E"
duration events, counters and gauges "C" counter events, one-off marks "i"
instants.

Discipline: a tracer is HOST-ONLY bookkeeping.  Never call one inside a
jitted/pallas/shard_map body — emission there would either be traced away
silently or force a host sync.  fp4lint's ``obs-in-jit`` rule enforces
this statically.  With tracing disabled, code paths hold the shared
``NULL_TRACER`` singleton whose methods are empty — near-zero call cost,
bit-identical behaviour.

This module is deliberately jax-free (stdlib only) so ``tools/check_env.py
--obs`` can drive a full scheduler lifecycle trace without an accelerator
stack.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Tuple

# Chrome trace-event required keys (validated by ``validate_events``).
REQUIRED_EVENT_KEYS = ("name", "ph", "ts", "pid", "tid")

# Event phases we emit: duration begin/end, counter, instant, metadata.
_PHASES = ("B", "E", "C", "i", "M")


class Counters:
    """Monotonic named totals — the counter substrate shared by ``Tracer``
    and ``serve/metrics.MetricsRecorder``.

    Mapping-like: ``dict(c)``, ``c["x"]``, ``"x" in c``, ``len(c)`` all
    work, so summaries that previously held a plain dict are unchanged.
    """

    __slots__ = ("_c",)

    def __init__(self, init: Optional[Dict[str, int]] = None):
        self._c: Dict[str, int] = dict(init) if init else {}

    def inc(self, name: str, delta: int = 1) -> int:
        total = self._c.get(name, 0) + delta
        self._c[name] = total
        return total

    def set(self, name: str, value: int) -> None:
        self._c[name] = value

    def get(self, name: str, default: int = 0) -> int:
        return self._c.get(name, default)

    def snapshot(self) -> Dict[str, int]:
        return dict(self._c)

    def clear(self) -> None:
        self._c.clear()

    # mapping protocol (enough for dict(...), iteration, membership)
    def __getitem__(self, name: str) -> int:
        return self._c[name]

    def __iter__(self):
        return iter(self._c)

    def __len__(self) -> int:
        return len(self._c)

    def __contains__(self, name: str) -> bool:
        return name in self._c

    def keys(self):
        return self._c.keys()

    def values(self):
        return self._c.values()

    def items(self):
        return self._c.items()

    def __repr__(self) -> str:
        return f"Counters({self._c!r})"


class NullTracer:
    """The disabled tracer: every method is an empty no-op.

    Shared singleton ``NULL_TRACER`` is what instrumented code holds when
    no tracer was passed — guard any non-trivial bookkeeping (e.g. jit
    cache-size polling) behind ``if tracer.enabled``.
    """

    __slots__ = ()
    enabled = False
    clock = "none"

    def set_time(self, t: int) -> None:
        pass

    def begin(self, track: str, name: str, ts: Optional[int] = None,
              **args: Any) -> None:
        pass

    def end(self, track: str, name: str, ts: Optional[int] = None,
            **args: Any) -> None:
        pass

    def instant(self, track: str, name: str, ts: Optional[int] = None,
                **args: Any) -> None:
        pass

    def counter(self, name: str, delta: int = 1,
                ts: Optional[int] = None) -> int:
        return 0

    def gauge(self, name: str, value: float, ts: Optional[int] = None,
              track: str = "gauges") -> None:
        pass

    @contextmanager
    def span(self, track: str, name: str, **args: Any) -> Iterator[None]:
        yield

    @property
    def counters(self) -> Counters:
        return Counters()

    @property
    def n_events(self) -> int:
        return 0

    @property
    def spans_opened(self) -> int:
        return 0

    @property
    def spans_closed(self) -> int:
        return 0

    def open_spans(self) -> Dict[Tuple[str, str], int]:
        return {}

    def trace_events(self) -> List[dict]:
        return []

    def export(self, path: str) -> str:
        raise RuntimeError("NULL_TRACER records nothing; nothing to export")


NULL_TRACER = NullTracer()


class Tracer:
    """Recording tracer: spans, counters, gauges on a simulated clock.

    ``clock`` names the time unit ("tick" for serve, "step" for train) and
    is stamped into the exported JSON so a trace is self-describing.  Set
    ``wall=True`` to additionally annotate each event with a
    ``wall`` arg (perf_counter seconds) — annotation only, assertions must
    never read it.
    """

    enabled = True

    def __init__(self, clock: str = "tick", process: str = "repro",
                 wall: bool = False):
        self.clock = clock
        self.process = process
        self.wall = wall
        self.counters = Counters()
        self.gauges: Dict[str, float] = {}
        self._now = 0
        self._events: List[dict] = []
        self._meta: List[dict] = []
        self._tids: Dict[str, int] = {}
        self._open: Dict[Tuple[str, str], int] = {}
        self._opened = 0
        self._closed = 0
        self._meta.append({"name": "process_name", "ph": "M", "ts": 0,
                           "pid": 1, "tid": 0,
                           "args": {"name": f"{process} [{clock} clock]"}})

    # ---- clock ---------------------------------------------------------

    def set_time(self, t: int) -> None:
        """Advance the simulated clock (scheduler tick / optimizer step)."""
        self._now = int(t)

    @property
    def now(self) -> int:
        return self._now

    # ---- emission ------------------------------------------------------

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids) + 1
            self._meta.append({"name": "thread_name", "ph": "M", "ts": 0,
                               "pid": 1, "tid": tid,
                               "args": {"name": track}})
        return tid

    def _emit(self, ph: str, track: str, name: str, ts: Optional[int],
              args: Dict[str, Any]) -> None:
        if self.wall:
            args = dict(args)
            args["wall"] = time.perf_counter()
        ev = {"name": name, "ph": ph,
              "ts": self._now if ts is None else int(ts),
              "pid": 1, "tid": self._tid(track)}
        if args:
            ev["args"] = args
        if ph == "i":
            ev["s"] = "t"        # instant scope: thread
        self._events.append(ev)

    def begin(self, track: str, name: str, ts: Optional[int] = None,
              **args: Any) -> None:
        key = (track, name)
        self._open[key] = self._open.get(key, 0) + 1
        self._opened += 1
        self._emit("B", track, name, ts, args)

    def end(self, track: str, name: str, ts: Optional[int] = None,
            **args: Any) -> None:
        key = (track, name)
        self._open[key] = self._open.get(key, 0) - 1
        if self._open[key] == 0:
            del self._open[key]
        self._closed += 1
        self._emit("E", track, name, ts, args)

    @contextmanager
    def span(self, track: str, name: str, **args: Any) -> Iterator[None]:
        self.begin(track, name, **args)
        try:
            yield
        finally:
            self.end(track, name)

    def instant(self, track: str, name: str, ts: Optional[int] = None,
                **args: Any) -> None:
        self._emit("i", track, name, ts, args)

    def counter(self, name: str, delta: int = 1,
                ts: Optional[int] = None) -> int:
        """Accumulate ``delta`` into a running total; emits a "C" event."""
        total = self.counters.inc(name, delta)
        self._emit("C", "counters", name, ts, {name: total})
        return total

    def gauge(self, name: str, value: float, ts: Optional[int] = None,
              track: str = "gauges") -> None:
        """Record an instantaneous value; emits a "C" event."""
        self.gauges[name] = value
        self._emit("C", track, name, ts, {name: value})

    # ---- introspection (span balance, self-checks) ---------------------

    @property
    def n_events(self) -> int:
        return len(self._events)

    @property
    def spans_opened(self) -> int:
        return self._opened

    @property
    def spans_closed(self) -> int:
        return self._closed

    def open_spans(self) -> Dict[Tuple[str, str], int]:
        """(track, name) -> nesting depth of spans begun but not ended.

        Empty at end-of-run means every request/tick span was balanced
        (an ``end`` without a ``begin`` shows up as a negative depth).
        """
        return dict(self._open)

    # ---- export --------------------------------------------------------

    def trace_events(self) -> List[dict]:
        """All events (metadata first) in Chrome trace-event dict form."""
        return self._meta + self._events

    def export(self, path: str) -> str:
        """Write Perfetto/chrome://tracing-loadable JSON; returns ``path``."""
        doc = {"traceEvents": self.trace_events(),
               "displayTimeUnit": "ms",
               "otherData": {"clock": self.clock, "process": self.process}}
        with open(path, "w") as f:
            json.dump(doc, f, separators=(",", ":"))
        return path


# ---- trace-file helpers (used by check_env --obs and tests) --------------


def load_trace(path: str) -> List[dict]:
    """Load an exported trace; accepts the object form or a bare array."""
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise ValueError(f"{path}: traceEvents is not a list")
    return events


def validate_events(events: List[dict]) -> List[str]:
    """Schema check: every event has the Chrome trace-event required keys,
    a known phase, and an int timestamp.  Returns a list of problems
    (empty == valid)."""
    problems: List[str] = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        missing = [k for k in REQUIRED_EVENT_KEYS if k not in ev]
        if missing:
            problems.append(f"event {i} ({ev.get('name')!r}): "
                            f"missing keys {missing}")
        if ev.get("ph") not in _PHASES:
            problems.append(f"event {i}: unknown phase {ev.get('ph')!r}")
        if not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i}: non-numeric ts {ev.get('ts')!r}")
    return problems
