"""The fp4lint rule set: six machine-checked invariants of this repo.

Every rule's docstring carries a minimal FIRING example (and its clean
twin where the fix is non-obvious); ``tests/test_lint.py`` executes those
examples against the rule.  Rules are registered in :data:`RULES` by
their kebab-case name — the name used in ``# fp4lint: disable=<name>``
pragmas and baseline entries.

Adding a rule: subclass :class:`Rule`, set ``name``/``summary``, write a
docstring with a firing example, implement ``check(ctx)`` yielding
``ctx.finding(self.name, node, message)``, and add an instance to
``RULES``.  Keep it stdlib-only — the pass must stay importable without
jax (``tools/check_env.py --lint`` runs before the dependency report).
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional

from repro.analysis.engine import (FileContext, Finding, dotted_name,
                                   is_const, terminal_name)


class Rule:
    name = "abstract"
    summary = ""

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        raise NotImplementedError


# ---- 1. rounding-policy -------------------------------------------------------


class RoundingPolicyRule(Rule):
    """Stochastic rounding stays on the backward/update path — the forward
    and serving paths are RtN (FP4 All the Way §rounding; Quartet II on SR
    placement for unbiased gradients).

    Fires on any construction of an SR quant spec — ``stochastic=True``
    keyword or ``.with_rounding(True)`` — in the forward-only scopes:
    ``serve/`` and ``models/`` files (module or function scope — an SR
    spec must not even be constructible there), ``kernels/`` serving
    paths (module scope or a ``*decode*`` / ``*draft*`` / ``*verify*``
    function — speculative decoding's draft and verify passes are
    forward passes: an SR draft would desync from the RtN verify and
    an SR verify would break bit-exactness vs sequential decode), and
    anywhere as an argument of a ``pack_quantize`` call (the packed
    weight store is RtN-only).

    FIRES (in src/repro/serve/ or src/repro/models/)::

        spec = BlockQuantSpec(stochastic=True)
        sr = NVFP4.with_rounding(True)

    FIRES (in src/repro/kernels/)::

        def verify_read(pool):
            return dequant(pool, NVFP4.with_rounding(True))

    CLEAN::

        spec = BlockQuantSpec()                  # RtN default
        bwd = NVFP4.with_rounding(True)          # in train/ or core/
    """

    name = "rounding-policy"
    summary = "SR spec constructed on a forward/serving path"

    @staticmethod
    def _is_sr_spec(node: ast.Call) -> bool:
        if any(kw.arg == "stochastic" and is_const(kw.value, True)
               for kw in node.keywords):
            return True
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "with_rounding"
                and node.args and is_const(node.args[0], True)):
            return True
        return False

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        fwd_file = ctx.in_serve or ctx.in_models
        if ctx.in_tests:
            fwd_file = False

        # function-name stack to classify kernels/ decode paths
        def walk(node, fn_stack: List[str]):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                fn_stack = fn_stack + [node.name]
            if isinstance(node, ast.Call):
                in_decode_kernel = ctx.in_kernels and (
                    not fn_stack or any(s in fn_stack[-1] for s in
                                        ("decode", "draft", "verify")))
                if self._is_sr_spec(node) and (fwd_file or in_decode_kernel):
                    where = ("serving/model" if fwd_file
                             else "kernel decode")
                    yield ctx.finding(
                        self.name, node,
                        f"stochastic-rounding spec constructed on a "
                        f"{where} path (forward/serving is RtN-only)")
                if terminal_name(node.func) == "pack_quantize":
                    for sub in ast.walk(node):
                        if (isinstance(sub, ast.Call) and sub is not node
                                and self._is_sr_spec(sub)):
                            yield ctx.finding(
                                self.name, sub,
                                "SR spec flows into pack_quantize "
                                "(packed weight store is RtN-only)")
            for child in ast.iter_child_nodes(node):
                yield from walk(child, fn_stack)

        yield from walk(ctx.tree, [])


# ---- 2. prng-reuse ------------------------------------------------------------


_SPLITTERS = {"split", "fold_in", "clone", "key_data", "wrap_key_data",
              "PRNGKey", "key"}


class PrngReuseRule(Rule):
    """Threefry keys are single-use: a key binding consumed by two
    ``jax.random.*`` sampling calls without an intervening ``split`` /
    ``fold_in`` rebinding replays the stream (PR 5's "root key split
    FIRST" bug).  Also fires on ``PRNGKey(<literal>)`` in library code
    (``src/``, excluding ``configs/``) — hard-coded seeds belong in
    configs, CLIs and tests, not inside the library.

    FIRES::

        key = jax.random.PRNGKey(seed)
        a = jax.random.normal(key, shape)
        b = jax.random.uniform(key, shape)       # same binding, reused

    CLEAN::

        key = jax.random.PRNGKey(seed)
        ka, kb = jax.random.split(key)
        a = jax.random.normal(ka, shape)
        b = jax.random.uniform(kb, shape)
    """

    name = "prng-reuse"
    summary = "PRNG key reused across sampling calls / literal seed"

    @staticmethod
    def _is_jax_random_call(node: ast.Call) -> Optional[str]:
        """-> terminal fn name for jax.random.* / random.* calls."""
        dn = dotted_name(node.func)
        if ".random." in dn or dn.startswith("random."):
            return terminal_name(node.func)
        return None

    def _scan_block(self, ctx: FileContext, body,
                    consumed: Optional[Dict[str, int]] = None,
                    gen: Optional[Dict[str, int]] = None
                    ) -> Iterator[Finding]:
        """Straight-line scan of one statement list: per-name generation
        counters; a sampling call consumes the binding's generation.
        Branch bodies recurse with COPIED state (exclusive branches never
        flag each other); nested defs are skipped here — ``check`` scans
        every function exactly once."""
        consumed = {} if consumed is None else consumed
        gen = {} if gen is None else gen

        def rebind(target):
            for n in ast.walk(target):
                if isinstance(n, ast.Name):
                    gen[n.id] = gen.get(n.id, 0) + 1

        def scan_expr(expr) -> Iterator[Finding]:
            for sub in ast.walk(expr):
                if not isinstance(sub, ast.Call):
                    continue
                fn = self._is_jax_random_call(sub)
                if fn is None or fn in _SPLITTERS:
                    continue
                for arg in sub.args[:1]:   # key is the first positional arg
                    if not isinstance(arg, ast.Name):
                        continue
                    g = gen.get(arg.id, 0)
                    if consumed.get(arg.id) == g:
                        yield ctx.finding(
                            self.name, sub,
                            f"key {arg.id!r} consumed by a second "
                            f"jax.random sampling call without an "
                            f"intervening split/fold_in rebinding")
                    consumed[arg.id] = g

        for node in body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if isinstance(node, ast.If):
                yield from scan_expr(node.test)
                for branch in (node.body, node.orelse):
                    yield from self._scan_block(ctx, branch,
                                                dict(consumed), dict(gen))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                yield from scan_expr(node.iter)
                rebind(node.target)        # loop var rebinds per iteration
                yield from self._scan_block(ctx, node.body,
                                            dict(consumed), dict(gen))
            elif isinstance(node, ast.While):
                yield from scan_expr(node.test)
                yield from self._scan_block(ctx, node.body,
                                            dict(consumed), dict(gen))
            elif isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    yield from scan_expr(item.context_expr)
                yield from self._scan_block(ctx, node.body, consumed, gen)
            elif isinstance(node, ast.Try):
                for blk in (node.body, node.orelse, node.finalbody):
                    yield from self._scan_block(ctx, blk,
                                                dict(consumed), dict(gen))
                for h in node.handlers:
                    yield from self._scan_block(ctx, h.body,
                                                dict(consumed), dict(gen))
            else:
                yield from scan_expr(node)
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        rebind(t)
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    rebind(node.target)

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._scan_block(ctx, node.body)
            if (isinstance(node, ast.Call)
                    and terminal_name(node.func) == "PRNGKey"
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, int)
                    and ctx.in_src and not ctx.in_configs
                    and not ctx.in_tests):
                yield ctx.finding(
                    self.name, node,
                    f"PRNGKey({node.args[0].value}) literal seed in "
                    f"library code — thread the seed from a config/CLI")


# ---- 3. spec-canonical --------------------------------------------------------


class SpecCanonicalRule(Rule):
    """PartitionSpecs must be in GSPMD normal form: trailing ``None`` dims
    stripped.  ``P(None, None)`` equals ``P()`` to GSPMD but NOT to the
    jit compile cache's sharding equality, so a non-canonical spec on a
    jit input silently fragments the cache into one entry per spelling
    (PR 6; ``distributed.specs.strip_trailing_none`` is the canonical
    form used everywhere else).

    FIRES::

        spec = P("model", None)
        sh = NamedSharding(mesh, PartitionSpec(None, None))

    CLEAN::

        spec = P("model")
        sh = NamedSharding(mesh, PartitionSpec())
    """

    name = "spec-canonical"
    summary = "PartitionSpec literal with trailing None dims"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = terminal_name(node.func)
            if fn not in ("PartitionSpec", "P"):
                continue
            if fn == "P" and dotted_name(node.func) not in (
                    "P", "jax.sharding.PartitionSpec"):
                continue                    # e.g. some_mod.P(...) helper
            if node.args and is_const(node.args[0], None) \
                    and all(is_const(a, None) for a in node.args):
                n = len(node.args)
                yield ctx.finding(
                    self.name, node,
                    f"all-replicated spec spelled with {n} explicit "
                    f"None dim(s) — use {fn}() (canonical form; "
                    f"spec equality keys the jit cache)")
            elif node.args and is_const(node.args[-1], None):
                yield ctx.finding(
                    self.name, node,
                    f"trailing None dim in {fn}(...) literal — strip it "
                    f"(GSPMD normalizes, the jit cache does not)")


# ---- 4. trace-hazard ----------------------------------------------------------


_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize", "nbytes"}
_STATIC_CALLS = {"len", "range", "enumerate", "zip"}


def _static_arg(node: ast.AST) -> bool:
    """True when coercing this expression is trace-safe: constants and
    shape/dtype metadata (static at trace time)."""
    if isinstance(node, ast.Constant):
        return True
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
            return True
        if isinstance(sub, ast.Call) \
                and terminal_name(sub.func) in _STATIC_CALLS:
            return True
    return False


class TraceHazardRule(Rule):
    """No host syncs or recompile triggers inside traced bodies: code that
    runs under ``jit`` / ``shard_map`` / ``pallas_call`` must not coerce
    traced values to Python scalars (``.item()``, ``int()`` / ``float()``
    / ``bool()``), materialize them on host (``np.asarray`` /
    ``np.array``), or format them into f-strings — each is at best a
    device sync per call and at worst a recompile per value (the hazards
    the engines' jit-cache==1 asserts only catch dynamically).

    Coercions of static metadata (``x.shape``, ``x.ndim``, ``len(...)``)
    are trace-safe and exempt, as are f-strings inside ``raise``
    statements — error messages format once at trace(-failure) time, not
    per executed step.

    FIRES::

        @jax.jit
        def f(x):
            return x * float(x.mean())       # host sync under trace

    CLEAN::

        @jax.jit
        def f(x):
            return x * x.mean()
        def host_loop(x):                    # not traced: coerce freely
            return float(jax.jit(lambda y: y.mean())(x))
    """

    name = "trace-hazard"
    summary = "host sync / recompile trigger inside a traced body"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ctx.traced:
            body = fn.body if not isinstance(fn, ast.Lambda) else [fn.body]
            for stmt in body:
                yield from self._scan(ctx, stmt)

    def _scan(self, ctx: FileContext, node: ast.AST) -> Iterator[Finding]:
        in_raise = {id(s) for r in ast.walk(node) if isinstance(r, ast.Raise)
                    for s in ast.walk(r) if isinstance(s, ast.JoinedStr)}
        for sub in ast.walk(node):
            # don't descend into nested defs here: they are themselves in
            # ctx.traced and get scanned once (avoids duplicate findings)
            if isinstance(sub, ast.Call):
                fn = terminal_name(sub.func)
                dn = dotted_name(sub.func)
                if (isinstance(sub.func, ast.Attribute)
                        and sub.func.attr == "item" and not sub.args):
                    yield ctx.finding(self.name, sub,
                                      ".item() syncs the host inside a "
                                      "traced body")
                elif fn in ("int", "float", "bool") \
                        and isinstance(sub.func, ast.Name) and sub.args \
                        and not _static_arg(sub.args[0]):
                    yield ctx.finding(
                        self.name, sub,
                        f"{fn}() coercion of a (possibly traced) value "
                        f"inside a traced body — host sync; hoist it or "
                        f"keep it on device")
                elif dn in ("np.asarray", "np.array", "numpy.asarray",
                            "numpy.array") and sub.args \
                        and not _static_arg(sub.args[0]):
                    yield ctx.finding(
                        self.name, sub,
                        f"{dn}() materializes a traced value on host "
                        f"inside a traced body (use jnp, or move to the "
                        f"host loop)")
            elif isinstance(sub, ast.JoinedStr) and id(sub) not in in_raise:
                if any(isinstance(v, ast.FormattedValue)
                       and not _static_arg(v.value)
                       for v in sub.values):
                    yield ctx.finding(
                        self.name, sub,
                        "f-string formats a (possibly traced) value "
                        "inside a traced body — per-value recompile / "
                        "host sync hazard")


# ---- 5. packed-dtype ----------------------------------------------------------


_PACKED_NAME_RE = re.compile(
    r"(^|_)(packed|codes?|nibbles?|scales|qscales)($|_)", re.IGNORECASE)
_WIDE_DTYPES = {"float32", "float64", "bfloat16", "float16",
                "int32", "int64"}


def _wide_dtype_arg(node: ast.AST) -> Optional[str]:
    """'float32' etc. when the expression names a wide dtype, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str) \
            and node.value in _WIDE_DTYPES:
        return node.value
    name = terminal_name(node)
    if name in _WIDE_DTYPES:
        return name
    return None


class PackedDtypeRule(Rule):
    """Packed 4-bit storage never widens off the 4-bit path: uint8 nibble
    codes and f8 block scales may only be upcast at the sanctioned
    dequant sites (``core/quantize.py`` and ``kernels/``), where the
    reconstruction stays bit-exact by construction.  Anywhere else, an
    ``astype`` of a packed/codes/scales-named value to a wide dtype is a
    silent fork off the packed path (it decodes nibble PAIRS as numbers,
    or re-rounds scales) and inflates the 0.56 bytes/param store.

    FIRES (outside core/quantize.py and kernels/)::

        w = qt.packed.astype(jnp.float32)
        s = scales.astype(jnp.bfloat16)

    CLEAN::

        w = qt.dequant()                     # the sanctioned reconstruction
        n = qt.packed.astype(jnp.uint8)      # storage-width cast
    """

    name = "packed-dtype"
    summary = "wide-dtype cast of packed codes/scales outside dequant sites"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        if ctx.in_kernels or ctx.path.endswith("core/quantize.py") \
                or ctx.in_tests:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args):
                continue
            recv = terminal_name(node.func.value)
            if recv is None or not _PACKED_NAME_RE.search(recv):
                continue
            wide = _wide_dtype_arg(node.args[0])
            if wide:
                yield ctx.finding(
                    self.name, node,
                    f"{recv}.astype({wide}) widens packed storage "
                    f"outside the sanctioned dequant sites "
                    f"(core/quantize.py, kernels/) — use .dequant()")


# ---- 6. obs-in-jit ------------------------------------------------------------


_TRACER_NAME_RE = re.compile(r"(^|_)(tracer|trc|obs)($|_)", re.IGNORECASE)
_TRACER_API = {"begin", "end", "instant", "counter", "gauge", "set_time",
               "span", "export"}


def _walk_same_trace(stmts) -> Iterator[ast.AST]:
    """Walk statements without descending into nested function defs —
    nested defs are themselves in ``ctx.traced`` when decorated, and
    otherwise are host closures whose bodies don't run under this
    trace."""
    stack: List[ast.AST] = list(stmts)
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            stack.append(child)


class ObsInJitRule(Rule):
    """Observability stays on the host: a ``Tracer`` emission (span,
    counter, gauge, clock update) inside a traced body is at best a
    side effect replayed only at trace time — the event records once
    per COMPILE, not once per executed step — and at worst a host sync
    on a traced value.  All instrumentation lives in the host loops
    (engine ticks, trainer steps); code under ``jit`` / ``shard_map``
    / ``pallas_call`` never sees the tracer (obs/trace.py).

    Fires on any Tracer-API call (``begin`` / ``end`` / ``instant`` /
    ``counter`` / ``gauge`` / ``set_time`` / ``span`` / ``export``) on
    a tracer-named receiver (``tracer`` / ``trc`` / ``obs``, with any
    dotted prefix such as ``self.tracer``), and on ``Tracer(...)``
    construction, inside a traced body.

    FIRES::

        @jax.jit
        def decode_step(x, tracer):
            tracer.counter("decode_steps")   # records once per compile
            return x

    CLEAN::

        def host_tick(x, tracer):
            tracer.counter("decode_steps")   # host loop: emit freely
            return decode_step(x)
    """

    name = "obs-in-jit"
    summary = "tracer emission inside a traced body"

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for fn in ctx.traced:
            body = fn.body if not isinstance(fn, ast.Lambda) else [fn.body]
            for sub in _walk_same_trace(body):
                if not isinstance(sub, ast.Call):
                    continue
                if terminal_name(sub.func) == "Tracer":
                    yield ctx.finding(
                        self.name, sub,
                        "Tracer constructed inside a traced body — "
                        "instrumentation is host-side only")
                    continue
                if not (isinstance(sub.func, ast.Attribute)
                        and sub.func.attr in _TRACER_API):
                    continue
                recv = terminal_name(sub.func.value)
                if recv is None or not _TRACER_NAME_RE.search(recv):
                    continue
                yield ctx.finding(
                    self.name, sub,
                    f"{recv}.{sub.func.attr}() emits telemetry inside a "
                    f"traced body — the event fires at trace time (once "
                    f"per compile), not per step; move it to the host "
                    f"loop")


RULES: Dict[str, Rule] = {r.name: r for r in (
    RoundingPolicyRule(), PrngReuseRule(), SpecCanonicalRule(),
    TraceHazardRule(), PackedDtypeRule(), ObsInJitRule())}


def all_rule_names():
    return sorted(RULES)
