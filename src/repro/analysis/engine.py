"""The fp4lint visitor engine: file scanning, pragmas, traced-scope maps.

Pure stdlib (``ast`` + ``tokenize``); rules live in ``rules.py`` and get a
:class:`FileContext` with everything precomputed once per file:

  * the parsed module and raw source lines;
  * the pragma map (``# fp4lint: disable=rule-a,rule-b`` comments — a
    pragma on a line silences that line; a pragma alone on its line also
    silences the line below it, for statements too long to annotate);
  * the TRACED-function set: functions that end up as jit / pallas_call /
    shard_map bodies, found from decorators (``@jax.jit``,
    ``@partial(jax.jit, ...)``) and call sites (``jax.jit(f)``,
    ``jax.jit(self._impl)``, ``pl.pallas_call(kernel, ...)``,
    ``shard_map(body, ...)``) — plus every function nested inside one;
  * scope classification of the file path (serve/models/kernels/tests/...)
    shared by the path-scoped rules.

Findings carry a line-number-independent baseline key
(``path:rule:stripped-source-line``) so grandfathered entries survive
unrelated edits above them.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import os
import re
import time
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# the tree the CLI and the tier-1 self-check walk (repo-relative)
DEFAULT_SCAN_DIRS = ("src", "tools", "benchmarks", "tests")

_PRAGMA_RE = re.compile(
    r"#\s*fp4lint\s*:\s*disable(?:\s*=\s*([\w,\s-]+))?", re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    path: str          # repo-relative, forward slashes
    line: int          # 1-based
    col: int
    rule: str
    message: str
    source: str        # stripped offending source line

    def key(self) -> str:
        """Baseline identity: line numbers excluded so entries survive
        edits elsewhere in the file."""
        return f"{self.path}:{self.rule}:{self.source}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} {self.message}\n"
                f"    {self.source}")


@dataclasses.dataclass
class LintStats:
    """Aggregate counters of one ``lint_paths`` run (bench artifact rows)."""

    files_scanned: int = 0
    findings: int = 0
    suppressed: int = 0          # pragma-silenced
    parse_errors: int = 0
    runtime_s: float = 0.0
    per_rule: Dict[str, int] = dataclasses.field(default_factory=dict)


# ---- helpers shared by the rules ----------------------------------------------


def terminal_name(node: ast.AST) -> Optional[str]:
    """Rightmost identifier of a Name/Attribute chain (``jax.random.split``
    -> ``split``); None for anything else."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def dotted_name(node: ast.AST) -> str:
    """Best-effort dotted form of a Name/Attribute chain ('' otherwise)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def is_const(node: ast.AST, value) -> bool:
    return isinstance(node, ast.Constant) and node.value is value


_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


class FileContext:
    """Everything a rule needs about one file, computed once."""

    def __init__(self, path: str, source: str):
        self.path = path.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source)
        self.pragmas, self.pragmas_standalone = _collect_pragmas(source)
        self.traced = _traced_functions(self.tree)
        # path scopes used by rules (posix-relative paths)
        p = self.path
        self.in_tests = p.startswith("tests/") or "/tests/" in p
        self.in_configs = "/configs/" in p
        self.in_serve = "/serve/" in p
        self.in_models = "/models/" in p
        self.in_kernels = "/kernels/" in p
        self.in_src = p.startswith("src/")

    def source_line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def suppressed(self, rule: str, lineno: int) -> bool:
        for ln in (lineno, lineno - 1):
            rules = self.pragmas.get(ln)
            if rules is None:
                continue
            if rules == "all" or rule in rules:
                # a standalone-pragma line covers the next line; a trailing
                # pragma covers only its own line
                if ln == lineno or self.pragmas_standalone.get(ln):
                    return True
        return False

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(path=self.path, line=node.lineno,
                       col=getattr(node, "col_offset", 0), rule=rule,
                       message=message, source=self.source_line(node.lineno))


def _collect_pragmas(source: str):
    """-> ({lineno: 'all' | set(rule names)}, {lineno: standalone?}) from
    ``# fp4lint: disable[=...]`` comments."""
    pragmas: Dict[int, object] = {}
    standalone: Dict[int, bool] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _PRAGMA_RE.search(tok.string)
            if not m:
                continue
            names = m.group(1)
            val = ("all" if not names else
                   {n.strip() for n in names.split(",") if n.strip()})
            ln = tok.start[0]
            prev = pragmas.get(ln)
            if isinstance(prev, set) and isinstance(val, set):
                val = prev | val
            pragmas[ln] = "all" if (prev == "all" or val == "all") else val
            standalone[ln] = tok.line[: tok.start[1]].strip() == ""
    except tokenize.TokenError:
        pass
    return pragmas, standalone


def _partial_target(call: ast.Call) -> Optional[str]:
    """``partial(f, ...)`` / ``functools.partial(f, ...)`` -> name of f."""
    if terminal_name(call.func) == "partial" and call.args:
        return terminal_name(call.args[0])
    return None


_TRACERS = {"jit", "pallas_call", "shard_map", "pjit"}


def _traced_functions(tree: ast.Module) -> Set[ast.AST]:
    """Function nodes whose bodies run under trace: decorator-marked,
    name-referenced at a jit/pallas_call/shard_map call site, or nested
    inside either."""
    traced_names: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if terminal_name(node.func) not in _TRACERS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            name = terminal_name(arg)
            if isinstance(arg, ast.Call):
                name = _partial_target(arg) or name
            if name:
                traced_names.add(name)

    def deco_is_tracer(deco: ast.AST) -> bool:
        return any(terminal_name(n) in _TRACERS for n in ast.walk(deco)
                   if isinstance(n, (ast.Name, ast.Attribute)))

    traced: Set[ast.AST] = set()

    def visit(node: ast.AST, inside: bool):
        here = inside
        if isinstance(node, _FUNC_NODES):
            here = (inside or node.name in traced_names
                    or any(deco_is_tracer(d) for d in node.decorator_list))
            if here:
                traced.add(node)
        elif isinstance(node, ast.Lambda) and inside:
            traced.add(node)
        for child in ast.iter_child_nodes(node):
            visit(child, here)

    visit(tree, False)
    return traced


# ---- drivers ------------------------------------------------------------------


def lint_source(source: str, path: str = "<string>",
                rules: Optional[Sequence] = None,
                stats: Optional[LintStats] = None) -> List[Finding]:
    """Lint one source string; returns pragma-filtered findings."""
    from repro.analysis.rules import RULES
    rules = list(RULES.values()) if rules is None else list(rules)
    ctx = FileContext(path, source)
    out: List[Finding] = []
    for rule in rules:
        for f in rule.check(ctx):
            if ctx.suppressed(f.rule, f.line):
                if stats is not None:
                    stats.suppressed += 1
                continue
            out.append(f)
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    if stats is not None:
        stats.findings += len(out)
        for f in out:
            stats.per_rule[f.rule] = stats.per_rule.get(f.rule, 0) + 1
    return out


def lint_file(path: str, root: str = ".",
              rules: Optional[Sequence] = None,
              stats: Optional[LintStats] = None) -> List[Finding]:
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    with open(path, encoding="utf-8") as f:
        source = f.read()
    try:
        return lint_source(source, rel, rules=rules, stats=stats)
    except SyntaxError as e:
        if stats is not None:
            stats.parse_errors += 1
        return [Finding(path=rel, line=e.lineno or 0, col=e.offset or 0,
                        rule="parse-error", message=f"syntax error: {e.msg}",
                        source=(e.text or "").strip())]


def iter_py_files(paths: Iterable[str], root: str = ".") -> List[str]:
    """Expand files/dirs into a deterministic sorted list of .py files."""
    out: Set[str] = set()
    for p in paths:
        ap = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(ap):
            out.add(ap)
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = sorted(d for d in dirnames
                                     if d not in ("__pycache__",))
                for fn in filenames:
                    if fn.endswith(".py"):
                        out.add(os.path.join(dirpath, fn))
    return sorted(out)


def lint_paths(paths: Optional[Iterable[str]] = None, root: str = ".",
               rules: Optional[Sequence] = None
               ) -> Tuple[List[Finding], LintStats]:
    """Lint files/dirs (default: the repo scan set) -> (findings, stats)."""
    t0 = time.perf_counter()
    stats = LintStats()
    findings: List[Finding] = []
    for f in iter_py_files(paths or DEFAULT_SCAN_DIRS, root):
        stats.files_scanned += 1
        findings.extend(lint_file(f, root=root, rules=rules, stats=stats))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    stats.findings = len(findings)
    stats.runtime_s = time.perf_counter() - t0
    return findings, stats
