"""Baseline file: grandfathered findings, checked in and diffed exactly.

A baseline entry is one line, the finding's line-number-independent key::

    path:rule:stripped-source-line

(Line numbers are deliberately absent so entries survive edits elsewhere
in the file; the stripped source line pins the entry to the offending
statement.)  ``tools/lint.py`` fails on BOTH directions of drift: a
finding not in the baseline (new violation) and a baseline entry no
finding matches (stale — the violation was fixed, so the entry must be
deleted).  ``tools/lint.py --update-baseline`` rewrites the file with a
deterministic sort so diffs are reviewable.

Duplicate keys are honest: two identical offending lines in one file
produce two identical entries, and the diff is a multiset comparison.
"""
from __future__ import annotations

import collections
import os
from typing import Iterable, List, Sequence, Tuple

HEADER = (
    "# fp4lint baseline — grandfathered findings, one 'path:rule:source'\n"
    "# key per line. Regenerate with: python tools/lint.py"
    " --update-baseline\n"
    "# New findings AND stale entries both fail the lint; fix the code or\n"
    "# update this file deliberately.\n")


def load_baseline(path: str) -> List[str]:
    """-> list of baseline keys (comments/blank lines skipped); [] when
    the file does not exist."""
    if not os.path.exists(path):
        return []
    out: List[str] = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.rstrip("\n")
            if not line.strip() or line.lstrip().startswith("#"):
                continue
            out.append(line)
    return out


def render_baseline(findings: Iterable) -> str:
    """Deterministic baseline text for a set of findings."""
    keys = sorted(f.key() for f in findings)
    body = "".join(k + "\n" for k in keys)
    return HEADER + body


def write_baseline(path: str, findings: Iterable) -> None:
    with open(path, "w", encoding="utf-8") as f:
        f.write(render_baseline(findings))


def baseline_diff(findings: Sequence, baseline: Sequence[str]
                  ) -> Tuple[List, List[str]]:
    """Multiset diff -> (new_findings, stale_entries).

    ``new_findings`` are Finding objects whose key is not covered by the
    baseline; ``stale_entries`` are baseline keys no current finding
    matches.  Both empty == the lint is exactly at its recorded state.
    """
    remaining = collections.Counter(baseline)
    new: List = []
    for f in findings:
        k = f.key()
        if remaining[k] > 0:
            remaining[k] -= 1
        else:
            new.append(f)
    stale = sorted(remaining.elements())
    return new, stale
