"""fp4lint: stdlib-``ast`` static analysis of this repo's FP4 invariants.

Jax-free by construction (nothing here imports jax, numpy or any other
third-party package), so the whole pass runs in tier-1 preflight
(``tools/check_env.py --lint``) and in the ``tools/lint.py`` CLI in well
under a second.

The five shipped rules encode conventions the paper makes explicit and
invariants past PRs fixed by hand:

  * ``rounding-policy`` — RtN forward / SR backward placement;
  * ``prng-reuse``      — threefry key stream discipline;
  * ``spec-canonical``  — PartitionSpec normal form (jit-cache hygiene);
  * ``trace-hazard``    — host syncs / recompiles inside jitted bodies;
  * ``packed-dtype``    — 4-bit codes stay on the 4-bit path.

See ``docs/lint.md`` for the rule catalog with firing examples, the
``# fp4lint: disable=RULE`` pragma and the baseline-file workflow.
"""
from repro.analysis.baseline import (baseline_diff, load_baseline,
                                     render_baseline, write_baseline)
from repro.analysis.engine import (DEFAULT_SCAN_DIRS, Finding, LintStats,
                                   lint_file, lint_paths, lint_source)
from repro.analysis.rules import RULES, all_rule_names

__all__ = [
    "DEFAULT_SCAN_DIRS", "Finding", "LintStats", "RULES", "all_rule_names",
    "baseline_diff", "lint_file", "lint_paths", "lint_source",
    "load_baseline", "render_baseline", "write_baseline",
]
