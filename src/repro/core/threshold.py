"""The paper's §4 gradient-to-noise monitor and √3 precision switch.

Theory (paper App. B.1): with SR gradient quantization (noise std σ_q per
coordinate), the expected loss decrease under the optimal step size stalls
once

    ‖∇L‖ / (σ_q · √d)  <  √3        (σ_critical = ‖∇L‖ / √(3d))

The monitor estimates σ_q *from the actual quantized-vs-exact gradient
residual* on a probe slice each step (no extra assumptions), tracks an EMA of
the ratio, and recommends switching the backward/update GEMMs to higher
precision (the QAF phase) when the EMA crosses √3.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

SQRT3 = 1.7320508075688772


@dataclasses.dataclass(frozen=True)
class ThresholdConfig:
    ema: float = 0.9
    threshold: float = SQRT3
    min_steps: int = 10      # ignore the noisy first steps


class ThresholdState(NamedTuple):
    ratio_ema: jax.Array     # EMA of ||g|| / (sigma_q sqrt(d))
    sigma_q: jax.Array       # last noise-std estimate
    step: jax.Array
    crossed: jax.Array       # bool: EMA below threshold (switch recommended)


def init() -> ThresholdState:
    return ThresholdState(jnp.asarray(1e9, jnp.float32),
                          jnp.zeros((), jnp.float32),
                          jnp.zeros((), jnp.int32),
                          jnp.zeros((), bool))


def estimate_sigma_q(exact: jax.Array, quantized: jax.Array) -> jax.Array:
    """Per-coordinate quantization-noise std from a probe tensor."""
    r = (quantized.astype(jnp.float32) - exact.astype(jnp.float32)).ravel()
    return jnp.sqrt(jnp.mean(r * r) + 1e-30)


def update(state: ThresholdState, grad_norm: jax.Array, n_params: int,
           sigma_q: jax.Array, cfg: ThresholdConfig) -> ThresholdState:
    """grad_norm: global ‖∇L‖ (fp32); n_params: d; sigma_q: probe estimate."""
    ratio = grad_norm / (sigma_q * jnp.sqrt(jnp.asarray(n_params,
                                                        jnp.float32)) + 1e-30)
    first = state.step < 1
    ema = jnp.where(first, ratio,
                    cfg.ema * state.ratio_ema + (1 - cfg.ema) * ratio)
    step = state.step + 1
    crossed = (ema < cfg.threshold) & (step >= cfg.min_steps)
    return ThresholdState(ema, sigma_q, step, crossed)


def probe_sigma_from_grads(exact_grads, quant_grads) -> jax.Array:
    """σ_q estimated over the concatenation of all gradient leaves."""
    num, den = jnp.zeros(()), jnp.zeros(())
    for e, q in zip(jax.tree.leaves(exact_grads), jax.tree.leaves(quant_grads)):
        r = (q.astype(jnp.float32) - e.astype(jnp.float32)).ravel()
        num += jnp.sum(r * r)
        den += r.size
    return jnp.sqrt(num / jnp.maximum(den, 1) + 1e-30)


def layer_ratio(grad_norm: float, sigma_q: float, n_params: int) -> float:
    """One layer's ‖g_i‖ / (σ_q·√d_i) — the per-layer §4 statistic.

    Pure-python floats (host-side telemetry: the trainer maps it over
    per-leaf gradient norms to flag layers whose own gradient signal has
    sunk under the √3 noise floor while the GLOBAL ratio still clears it
    — the per-layer early warning the global EMA averages away)."""
    import math
    return float(grad_norm) / (float(sigma_q)
                               * math.sqrt(max(int(n_params), 1)) + 1e-30)
