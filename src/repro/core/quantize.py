"""Block (micro-scaled) quantization: NVFP4, MXFP4 and the paper's sweeps.

A *block-quantized* tensor stores, per contiguous block of ``block`` elements
along the blocking axis:

  * FP4 (``data_fmt``, default E2M1) codes, and
  * one shared scale in ``scale_fmt`` (E4M3 for NVFP4, E8M0 for MXFP4), and
  * (optionally, ``two_level=True``) one per-tensor scale that normalises the
    block scales into the scale format's representable range — the NVFP4
    hardware convention.  We round the tensor scale to a power of two so that
    ``codes * block_scale * tensor_scale`` stays exactly representable in
    bf16 (2-bit significand x 4-bit significand x 2^k <= 8-bit significand);
    see DESIGN.md §4.

The blocking axis must be the GEMM *contraction* axis of the operand as
consumed (this is what Blackwell block-scaled MMA requires, and what the
paper's six quantization points mean).  Operands therefore get re-quantized
per GEMM, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.core.formats import FloatFormat, get_format


@dataclasses.dataclass(frozen=True)
class BlockQuantSpec:
    """How to block-quantize one GEMM operand."""

    data_fmt: str = "e2m1"
    scale_fmt: str = "e4m3"
    block: int = 16
    two_level: bool = True     # per-tensor pow2 scale under the block scale
    stochastic: bool = False   # SR (True) or RtN (False)

    @property
    def data(self) -> FloatFormat:
        return get_format(self.data_fmt)

    @property
    def scale(self) -> FloatFormat:
        return get_format(self.scale_fmt)

    def with_rounding(self, stochastic: bool) -> "BlockQuantSpec":
        return dataclasses.replace(self, stochastic=stochastic)


NVFP4 = BlockQuantSpec(data_fmt="e2m1", scale_fmt="e4m3", block=16,
                       two_level=True)
MXFP4 = BlockQuantSpec(data_fmt="e2m1", scale_fmt="e8m0", block=32,
                       two_level=False)


class QuantizedTensor(NamedTuple):
    """codes * scales (block-broadcast) * tscale reconstructs the tensor.

    ``codes`` hold *dequantized-grid* values (exact E2M1 grid points) in the
    original dtype; ``scales`` has shape = codes.shape with the blocking axis
    divided by ``block``; ``tscale`` is a scalar (1.0 when two_level=False).
    """

    codes: jax.Array
    scales: jax.Array
    tscale: jax.Array
    axis: int
    block: int

    def dequant(self) -> jax.Array:
        s = jnp.repeat(self.scales, self.block, axis=self.axis)
        return (self.codes * s * self.tscale).astype(self.codes.dtype)


def _norm_axis(ndim: int, axis: int) -> int:
    return axis % ndim


def _blocked(x: jax.Array, axis: int, block: int) -> jax.Array:
    """Reshape so the blocking axis becomes (..., nblocks, block, ...)."""
    axis = _norm_axis(x.ndim, axis)
    if x.shape[axis] % block != 0:
        raise ValueError(
            f"axis {axis} of shape {x.shape} not divisible by block {block}")
    new_shape = x.shape[:axis] + (x.shape[axis] // block, block) + x.shape[axis + 1:]
    return x.reshape(new_shape)


def _block_scales(absmax: jax.Array, spec: BlockQuantSpec,
                  tscale: jax.Array) -> jax.Array:
    """Quantized per-block scales from per-block absmax (fp32 in/out)."""
    data_max = spec.data.max
    if spec.scale_fmt == "e8m0":
        # OCP MX rule: scale = 2^(floor(log2 amax) - emax_elem); here tscale==1.
        scale = formats.e8m0_floor(absmax) / (2.0 ** spec.data.emax)
        scale = jnp.where(absmax > 0, scale, 1.0)
        return scale
    raw = absmax / (data_max * tscale)
    scale = formats.quantize_rtn(raw, spec.scale)
    scale = jnp.where(scale > 0, scale, 1.0)
    return scale


def _tensor_scale(x_abs_max: jax.Array, spec: BlockQuantSpec) -> jax.Array:
    """Power-of-two tensor scale mapping the largest block scale into range."""
    if not spec.two_level:
        return jnp.ones((), dtype=jnp.float32)
    target = spec.data.max * spec.scale.max          # e.g. 6 * 448
    raw = x_abs_max / target
    # round *up* to a power of two so no block scale can clip (ldexp: exact)
    _, k = jnp.frexp(raw.astype(jnp.float32))        # raw = m * 2^k, m in [.5,1)
    ts = jnp.ldexp(jnp.ones((), jnp.float32), k)     # 2^ceil(log2 raw)
    return jnp.where(x_abs_max > 0, ts, jnp.ones((), jnp.float32))


def block_quantize(x: jax.Array, spec: BlockQuantSpec, *, axis: int = -1,
                   key: Optional[jax.Array] = None,
                   u: Optional[jax.Array] = None) -> QuantizedTensor:
    """Quantize x to (codes, scales, tscale) per ``spec`` along ``axis``.

    SR randomness: pass either ``key`` (threefry; statistics tests) or ``u``
    — uniforms in [0,1) of x.shape, e.g. from ``formats.counter_bits``,
    which XLA fuses into the quantize chain (the FQT hot path).
    """
    axis = _norm_axis(x.ndim, axis)
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    xb = _blocked(xf, axis, spec.block)              # (..., nb, B, ...)
    baxis = axis + 1                                 # the size-B axis
    absmax = jnp.max(jnp.abs(xb), axis=baxis)        # (..., nb, ...)
    tscale = _tensor_scale(jnp.max(jnp.abs(xf)), spec)
    scales = _block_scales(absmax, spec, tscale)     # (..., nb, ...)
    denom = jnp.expand_dims(scales, baxis) * tscale
    if spec.stochastic and u is not None:
        codes = formats.quantize_sr_with_u(
            xb / denom, spec.data, _blocked(u.astype(jnp.float32), axis,
                                            spec.block))
    else:
        codes = formats.quantize(xb / denom, spec.data,
                                 stochastic=spec.stochastic, key=key)
    codes = codes.reshape(x.shape).astype(orig_dtype)
    return QuantizedTensor(codes=codes, scales=scales.astype(orig_dtype),
                           tscale=tscale, axis=axis, block=spec.block)


def fake_quant(x: jax.Array, spec: BlockQuantSpec, *, axis: int = -1,
               key: Optional[jax.Array] = None,
               u: Optional[jax.Array] = None) -> jax.Array:
    """Quantize-dequantize in one step (the FQT simulation primitive)."""
    return block_quantize(x, spec, axis=axis, key=key, u=u).dequant()


def scale_health(x: jax.Array, spec: BlockQuantSpec, *,
                 axis: int = -1) -> dict:
    """Block-scale saturation/underflow counts for telemetry (host-side).

    Replays the ``_block_scales`` rounding on ``x`` and counts blocks
    whose raw scale exceeds the scale format's max (E4M3: 448 — the
    two-level tensor scale should make this impossible, so a nonzero
    count flags a scaling bug or an overflowing tensor) or whose nonzero
    absmax rounds to a zero scale (underflow — ``_block_scales`` clamps
    it to 1.0, quantizing the whole block to zero).  Returns plain ints;
    call OUTSIDE jit (this is trainer telemetry, not a training op).
    """
    axis = _norm_axis(x.ndim, axis)
    xf = jnp.asarray(x).astype(jnp.float32)
    xb = _blocked(xf, axis, spec.block)
    absmax = jnp.max(jnp.abs(xb), axis=axis + 1)
    tscale = _tensor_scale(jnp.max(jnp.abs(xf)), spec)
    if spec.scale_fmt == "e8m0":
        scale = formats.e8m0_floor(absmax) / (2.0 ** spec.data.emax)
        saturated = jnp.zeros((), jnp.int32)  # E8M0 spans the fp32 range
        underflow = jnp.sum((scale <= 0) & (absmax > 0))
    else:
        raw = absmax / (spec.data.max * tscale)
        scale = formats.quantize_rtn(raw, spec.scale)
        saturated = jnp.sum(raw > spec.scale.max)
        underflow = jnp.sum((scale <= 0) & (absmax > 0))
    return {"blocks": int(absmax.size), "saturated": int(saturated),
            "underflow": int(underflow)}


# ---- packed storage (serving weight store / checkpoint / cache paths) --------

# E2M1 magnitude grid, indexed by the 3 low nibble bits (matches the
# ml_dtypes.float4_e2m1fn bit layout: s eem, codes 0..7 -> these values).
_E2M1_GRID = np.array([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], np.float32)


def pack_e2m1(codes: jax.Array) -> jax.Array:
    """Pack E2M1 grid values into nibbles, two per uint8 (last axis even).

    Arithmetic encode (no float4 dtype — jax<0.5 cannot hold float4 arrays):
    nibble = sign<<3 | grid_index, the float4_e2m1fn bit layout.  ``codes``
    must hold exact grid values (the quantizers' output), any float dtype.
    """
    if codes.shape[-1] % 2:
        raise ValueError(f"last axis must be even to pack, got {codes.shape}")
    absv = jnp.abs(codes).astype(jnp.float32)
    idx = jnp.searchsorted(jnp.asarray(_E2M1_GRID), absv).astype(jnp.uint8)
    sign = (codes.astype(jnp.float32) < 0).astype(jnp.uint8)
    nib = (sign << 3) | idx
    lo, hi = nib[..., 0::2], nib[..., 1::2]
    return lo | (hi << 4)


def unpack_e2m1(packed: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Inverse of ``pack_e2m1``: uint8 nibble pairs -> exact E2M1 grid values."""
    lo = packed & 0x7
    hi = (packed >> 4) & 0x7
    mag = jnp.asarray(_E2M1_GRID)
    vlo = mag[lo] * jnp.where(packed & 0x8, -1.0, 1.0)
    vhi = mag[hi] * jnp.where(packed & 0x80, -1.0, 1.0)
    stacked = jnp.stack([vlo, vhi], axis=-1)
    flat = stacked.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
    return flat.astype(dtype)


@dataclasses.dataclass(frozen=True)
class PackedQuantizedTensor:
    """Quantize-once packed NVFP4 storage: 4-bit codes + block scales.

    The serving-side counterpart of ``QuantizedTensor``: E2M1 codes are
    nibble-packed two-per-uint8 along the LAST axis (``packed``), the block
    scales live in ``scales`` (float8_e4m3fn when the scale format is E4M3,
    else the source dtype) and ``tscale`` is the per-tensor pow2 scale —
    one per leading *batch* slice when the weight is a stacked layer/expert
    array, so a scan/vmap slice of this pytree is exactly the per-matrix
    quantization the fake-quant forward computes.

    ``dequant()`` reproduces ``QuantizedTensor.dequant()`` BIT-EXACTLY (all
    three factors are exactly representable in bf16 — see module docstring),
    which is what keeps packed serving token-identical to the QAF forward.

    ``axis`` is the blocking axis as a NEGATIVE index (so the same metadata
    stays valid when leading batch dims are sliced away by scan/vmap).
    """

    packed: jax.Array          # uint8, shape = logical[:-1] + (last/2,)
    scales: jax.Array          # logical shape with axis divided by block
    tscale: jax.Array          # f32, shape = leading batch dims (or scalar)
    axis: int                  # negative blocking-axis index
    block: int
    dtype_name: str = "bfloat16"     # dequant target dtype

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_name)

    @property
    def shape(self):
        return self.packed.shape[:-1] + (self.packed.shape[-1] * 2,)

    @property
    def ndim(self) -> int:
        return self.packed.ndim

    def nbytes(self) -> int:
        """Stored bytes (codes + scales + tscale)."""
        return int(self.packed.size * self.packed.dtype.itemsize
                   + self.scales.size * self.scales.dtype.itemsize
                   + self.tscale.size * 4)

    def wire_nbytes(self) -> int:
        """Bytes an FSDP-style all-gather of this tensor moves: the nibble
        codes + block scales ARE the wire format (~4.5 bits/param for
        NVFP4 vs 16 for a bf16 gather); the per-slice tscale is replicated
        and never travels."""
        return int(self.packed.size * self.packed.dtype.itemsize
                   + self.scales.size * self.scales.dtype.itemsize)

    def map_leaves(self, f) -> "PackedQuantizedTensor":
        """Apply ``f(name, array)`` to the data leaves (packed/scales/
        tscale), keeping metadata — the hook the sharding layer uses to
        attach per-leaf partition specs / device placements
        (distributed/sharding.spec_for_packed)."""
        return dataclasses.replace(
            self, packed=f("packed", self.packed),
            scales=f("scales", self.scales),
            tscale=f("tscale", self.tscale))

    def dequant(self) -> jax.Array:
        """codes * block_scales * tscale, bit-identical to the fake-quant
        (QuantizedTensor) reconstruction of the same tensor."""
        dt = self.dtype
        codes = unpack_e2m1(self.packed, dtype=dt)
        s = jnp.repeat(self.scales.astype(dt), self.block, axis=self.axis)
        t = self.tscale.reshape(
            self.tscale.shape + (1,) * (codes.ndim - self.tscale.ndim))
        return (codes * s * t).astype(dt)


jax.tree_util.register_dataclass(
    PackedQuantizedTensor,
    data_fields=["packed", "scales", "tscale"],
    meta_fields=["axis", "block", "dtype_name"])


def _pack_scales(scales: jax.Array, spec: BlockQuantSpec) -> jax.Array:
    """Store E4M3 block scales in float8 (exact: they lie on the e4m3 grid);
    other scale formats keep their source dtype."""
    if spec.scale_fmt == "e4m3":
        return scales.astype(jnp.float8_e4m3fn)
    return scales


def pack_quantized(qt: QuantizedTensor,
                   spec: BlockQuantSpec = NVFP4) -> PackedQuantizedTensor:
    """Convert a QuantizedTensor (dequantized-grid codes) to packed storage."""
    if spec.data_fmt != "e2m1":
        raise ValueError("packed storage is E2M1-only")
    return PackedQuantizedTensor(
        packed=pack_e2m1(qt.codes),
        scales=_pack_scales(qt.scales, spec),
        tscale=jnp.asarray(qt.tscale, jnp.float32),
        axis=qt.axis - qt.codes.ndim,
        block=qt.block,
        dtype_name=jnp.dtype(qt.codes.dtype).name)


# ---- KV-cache row quantization (serving decode path) -------------------------
#
# The decode-attention analogue of the packed weight store: cache rows are
# block-quantized along the HEAD dim (the qk^T contraction axis, so score
# tiles can dequantize K blocks in-register) with RtN — the paper's
# inference-compatible forward rounding.  Unlike weights, cache slots are
# written incrementally (prefill + one row per decoded token), so there is NO
# per-tensor second-level scale: a global absmax over future tokens cannot be
# known at append time.  Each block therefore carries a self-contained scale:
#
#   nvfp4:  E2M1 nibble codes (2/uint8) + one float8_e4m3fn scale per
#           ``block`` elements      -> 0.5 + 1/16 = 0.5625 bytes/elem (3.56x)
#   fp8:    float8_e4m3fn codes + one bf16 scale per ``block`` elements
#                                   -> 1 + 2/16   = 1.125  bytes/elem (1.78x)
#   bf16:   unquantized escape hatch (models/layers.KVCache).

KV_CACHE_FORMATS = ("bf16", "nvfp4", "fp8")


def kv_quant_rows(x: jax.Array, fmt: str, block: int = 16):
    """Quantize cache rows along the last (head) dim.  Returns (codes, scales).

    ``x``: (..., D) with D % block == 0.  RtN only (forward path).  Codes are
    storage-dtype (uint8 nibble pairs for nvfp4, float8_e4m3fn for fp8);
    scales have the last axis divided by ``block``.
    """
    if fmt not in ("nvfp4", "fp8"):
        raise ValueError(f"kv_quant_rows: unknown format {fmt!r}")
    e4m3 = get_format("e4m3")
    xf = x.astype(jnp.float32)
    xb = _blocked(xf, -1, block)                      # (..., nb, B)
    absmax = jnp.max(jnp.abs(xb), axis=-1)            # (..., nb)
    if fmt == "nvfp4":
        e2m1 = get_format("e2m1")
        scales = formats.quantize_rtn(absmax / e2m1.max, e4m3)
        scales = jnp.where(scales > 0, scales, 1.0)
        codes = formats.quantize_rtn(xb / scales[..., None], e2m1)
        return (pack_e2m1(codes.reshape(x.shape)),
                scales.astype(jnp.float8_e4m3fn))
    # fp8: scale each block into the e4m3 range; bf16 scale (rounded before
    # use so the stored scale is exactly the one the codes were built with)
    scales = jnp.where(absmax > 0, absmax / e4m3.max, 1.0
                       ).astype(jnp.bfloat16)
    codes = formats.quantize_rtn(
        xb / scales.astype(jnp.float32)[..., None], e4m3)
    return (codes.reshape(x.shape).astype(jnp.float8_e4m3fn),
            scales)


def kv_dequant(codes: jax.Array, scales: jax.Array, fmt: str,
               block: int = 16, dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of ``kv_quant_rows``: reconstruct (..., D) rows in ``dtype``."""
    if fmt == "nvfp4":
        vals = unpack_e2m1(codes, dtype=jnp.float32)
    elif fmt == "fp8":
        vals = codes.astype(jnp.float32)
    else:
        raise ValueError(f"kv_dequant: unknown format {fmt!r}")
    s = jnp.repeat(scales.astype(jnp.float32), block, axis=-1)
    return (vals * s).astype(dtype)


def kv_bytes_per_elem(fmt: str, block: int = 16) -> float:
    """Stored cache bytes per logical K/V element for ``fmt``."""
    if fmt == "bf16":
        return 2.0
    if fmt == "nvfp4":
        return 0.5 + 1.0 / block
    if fmt == "fp8":
        return 1.0 + 2.0 / block
    raise ValueError(f"unknown kv cache format {fmt!r}")


def pack_quantize(x: jax.Array, spec: BlockQuantSpec = NVFP4, *,
                  axis: int = -2, batch_dims: int = 0
                  ) -> PackedQuantizedTensor:
    """Quantize-once packing of a weight (RtN), optionally batched.

    ``batch_dims`` leading axes are treated as independent tensors (stacked
    layer / expert weights): the per-tensor pow2 scale is computed per slice,
    so slicing the result along those axes (lax.scan / vmap) yields exactly
    ``block_quantize(x[i], spec, axis=...)`` — the invariant that makes the
    packed store bit-identical to the per-GEMM fake-quant forward.
    """
    if spec.data_fmt != "e2m1":
        raise ValueError("packed storage is E2M1-only")
    if spec.stochastic:
        raise ValueError("packed weight store is RtN (forward) only")
    nd = x.ndim
    ax = _norm_axis(nd, axis)
    if ax < batch_dims:
        raise ValueError(f"blocking axis {ax} inside batch dims {batch_dims}")
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    xb = _blocked(xf, ax, spec.block)                  # (..., nb, B, ...)
    absmax = jnp.max(jnp.abs(xb), axis=ax + 1)         # (..., nb, ...)
    tmax = jnp.max(jnp.abs(xf), axis=tuple(range(batch_dims, nd)))
    # batch-shaped even for two_level=False (where _tensor_scale returns a
    # scalar 1.0): every data field must carry the leading batch dims or
    # lax.scan/vmap cannot slice the pytree
    tscale = jnp.broadcast_to(_tensor_scale(tmax, spec), tmax.shape)
    ts_b = tscale.reshape(tscale.shape + (1,) * (absmax.ndim - tscale.ndim))
    scales = _block_scales(absmax, spec, ts_b)
    denom = jnp.expand_dims(scales, ax + 1) * jnp.expand_dims(ts_b, ax + 1)
    codes = formats.quantize(xb / denom, spec.data)
    codes = codes.reshape(x.shape).astype(orig_dtype)
    return PackedQuantizedTensor(
        packed=pack_e2m1(codes),
        scales=_pack_scales(scales.astype(orig_dtype), spec),
        tscale=tscale.astype(jnp.float32),
        axis=ax - nd,
        block=spec.block,
        dtype_name=jnp.dtype(orig_dtype).name)
