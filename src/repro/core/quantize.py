"""Block (micro-scaled) quantization: NVFP4, MXFP4 and the paper's sweeps.

A *block-quantized* tensor stores, per contiguous block of ``block`` elements
along the blocking axis:

  * FP4 (``data_fmt``, default E2M1) codes, and
  * one shared scale in ``scale_fmt`` (E4M3 for NVFP4, E8M0 for MXFP4), and
  * (optionally, ``two_level=True``) one per-tensor scale that normalises the
    block scales into the scale format's representable range — the NVFP4
    hardware convention.  We round the tensor scale to a power of two so that
    ``codes * block_scale * tensor_scale`` stays exactly representable in
    bf16 (2-bit significand x 4-bit significand x 2^k <= 8-bit significand);
    see DESIGN.md §4.

The blocking axis must be the GEMM *contraction* axis of the operand as
consumed (this is what Blackwell block-scaled MMA requires, and what the
paper's six quantization points mean).  Operands therefore get re-quantized
per GEMM, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import formats
from repro.core.formats import FloatFormat, get_format


@dataclasses.dataclass(frozen=True)
class BlockQuantSpec:
    """How to block-quantize one GEMM operand."""

    data_fmt: str = "e2m1"
    scale_fmt: str = "e4m3"
    block: int = 16
    two_level: bool = True     # per-tensor pow2 scale under the block scale
    stochastic: bool = False   # SR (True) or RtN (False)

    @property
    def data(self) -> FloatFormat:
        return get_format(self.data_fmt)

    @property
    def scale(self) -> FloatFormat:
        return get_format(self.scale_fmt)

    def with_rounding(self, stochastic: bool) -> "BlockQuantSpec":
        return dataclasses.replace(self, stochastic=stochastic)


NVFP4 = BlockQuantSpec(data_fmt="e2m1", scale_fmt="e4m3", block=16,
                       two_level=True)
MXFP4 = BlockQuantSpec(data_fmt="e2m1", scale_fmt="e8m0", block=32,
                       two_level=False)


class QuantizedTensor(NamedTuple):
    """codes * scales (block-broadcast) * tscale reconstructs the tensor.

    ``codes`` hold *dequantized-grid* values (exact E2M1 grid points) in the
    original dtype; ``scales`` has shape = codes.shape with the blocking axis
    divided by ``block``; ``tscale`` is a scalar (1.0 when two_level=False).
    """

    codes: jax.Array
    scales: jax.Array
    tscale: jax.Array
    axis: int
    block: int

    def dequant(self) -> jax.Array:
        s = jnp.repeat(self.scales, self.block, axis=self.axis)
        return (self.codes * s * self.tscale).astype(self.codes.dtype)


def _norm_axis(ndim: int, axis: int) -> int:
    return axis % ndim


def _blocked(x: jax.Array, axis: int, block: int) -> jax.Array:
    """Reshape so the blocking axis becomes (..., nblocks, block, ...)."""
    axis = _norm_axis(x.ndim, axis)
    if x.shape[axis] % block != 0:
        raise ValueError(
            f"axis {axis} of shape {x.shape} not divisible by block {block}")
    new_shape = x.shape[:axis] + (x.shape[axis] // block, block) + x.shape[axis + 1:]
    return x.reshape(new_shape)


def _block_scales(absmax: jax.Array, spec: BlockQuantSpec,
                  tscale: jax.Array) -> jax.Array:
    """Quantized per-block scales from per-block absmax (fp32 in/out)."""
    data_max = spec.data.max
    if spec.scale_fmt == "e8m0":
        # OCP MX rule: scale = 2^(floor(log2 amax) - emax_elem); here tscale==1.
        scale = formats.e8m0_floor(absmax) / (2.0 ** spec.data.emax)
        scale = jnp.where(absmax > 0, scale, 1.0)
        return scale
    raw = absmax / (data_max * tscale)
    scale = formats.quantize_rtn(raw, spec.scale)
    scale = jnp.where(scale > 0, scale, 1.0)
    return scale


def _tensor_scale(x_abs_max: jax.Array, spec: BlockQuantSpec) -> jax.Array:
    """Power-of-two tensor scale mapping the largest block scale into range."""
    if not spec.two_level:
        return jnp.ones((), dtype=jnp.float32)
    target = spec.data.max * spec.scale.max          # e.g. 6 * 448
    raw = x_abs_max / target
    # round *up* to a power of two so no block scale can clip (ldexp: exact)
    _, k = jnp.frexp(raw.astype(jnp.float32))        # raw = m * 2^k, m in [.5,1)
    ts = jnp.ldexp(jnp.ones((), jnp.float32), k)     # 2^ceil(log2 raw)
    return jnp.where(x_abs_max > 0, ts, jnp.ones((), jnp.float32))


def block_quantize(x: jax.Array, spec: BlockQuantSpec, *, axis: int = -1,
                   key: Optional[jax.Array] = None,
                   u: Optional[jax.Array] = None) -> QuantizedTensor:
    """Quantize x to (codes, scales, tscale) per ``spec`` along ``axis``.

    SR randomness: pass either ``key`` (threefry; statistics tests) or ``u``
    — uniforms in [0,1) of x.shape, e.g. from ``formats.counter_bits``,
    which XLA fuses into the quantize chain (the FQT hot path).
    """
    axis = _norm_axis(x.ndim, axis)
    orig_dtype = x.dtype
    xf = x.astype(jnp.float32)
    xb = _blocked(xf, axis, spec.block)              # (..., nb, B, ...)
    baxis = axis + 1                                 # the size-B axis
    absmax = jnp.max(jnp.abs(xb), axis=baxis)        # (..., nb, ...)
    tscale = _tensor_scale(jnp.max(jnp.abs(xf)), spec)
    scales = _block_scales(absmax, spec, tscale)     # (..., nb, ...)
    denom = jnp.expand_dims(scales, baxis) * tscale
    if spec.stochastic and u is not None:
        codes = formats.quantize_sr_with_u(
            xb / denom, spec.data, _blocked(u.astype(jnp.float32), axis,
                                            spec.block))
    else:
        codes = formats.quantize(xb / denom, spec.data,
                                 stochastic=spec.stochastic, key=key)
    codes = codes.reshape(x.shape).astype(orig_dtype)
    return QuantizedTensor(codes=codes, scales=scales.astype(orig_dtype),
                           tscale=tscale, axis=axis, block=spec.block)


def fake_quant(x: jax.Array, spec: BlockQuantSpec, *, axis: int = -1,
               key: Optional[jax.Array] = None,
               u: Optional[jax.Array] = None) -> jax.Array:
    """Quantize-dequantize in one step (the FQT simulation primitive)."""
    return block_quantize(x, spec, axis=axis, key=key, u=u).dequant()


# ---- packed storage (checkpoint / cache paths; not MXU operands) -------------


def pack_e2m1(codes: jax.Array) -> jax.Array:
    """Pack E2M1 grid values into nibbles, two per uint8 (last axis even)."""
    import ml_dtypes  # noqa: F401  (registers float4_e2m1fn)
    fp4 = codes.astype(jnp.float4_e2m1fn)
    bits = jax.lax.bitcast_convert_type(fp4, jnp.uint4).astype(jnp.uint8)
    lo, hi = bits[..., 0::2], bits[..., 1::2]
    return lo | (hi << 4)


def unpack_e2m1(packed: jax.Array, dtype=jnp.float32) -> jax.Array:
    lo = (packed & 0xF).astype(jnp.uint4)
    hi = (packed >> 4).astype(jnp.uint4)
    stacked = jnp.stack([lo, hi], axis=-1)
    flat = stacked.reshape(*packed.shape[:-1], packed.shape[-1] * 2)
    fp4 = jax.lax.bitcast_convert_type(flat, jnp.float4_e2m1fn)
    return fp4.astype(dtype)
