"""Fully-quantized-training matmul: the paper's six quantization points.

The paper (eqs. 1-6) quantizes *both operands of all three training GEMMs*:

  [Forward]   z = Q(W) Q(a)           -> points  fwd_w (RtN), fwd_a (RtN)
  [Backward]  g_in = Q(W^T) Q(delta)  -> points  bwd_w (RtN), bwd_g (SR)
  [Update]    dW = Q(delta) Q(a^T)    -> points  upd_g (SR),  upd_a (SR)

``fp4_matmul`` is a custom_vjp matmul that applies an independent
``BlockQuantSpec`` (format, block size, scale format, rounding mode) at each
of the six points, with blocks always along the contraction axis of the GEMM
in which the operand is consumed (weights/activations/grads are therefore
re-quantized per GEMM, exactly as block-scaled FP4 hardware requires).

Randomness for stochastic rounding is threaded as an explicit uint32 ``seed``
operand (counter-based, derived per-layer/per-step by the caller), so training
is deterministic and replayable after checkpoint restart.

The straight-through estimator is implicit: the backward rule differentiates
the *unquantized* matmul and then re-quantizes its operands, which is exactly
eqs. (5)-(6) and is also what the paper's Gaudi2 simulation does.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, FrozenSet

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import (BlockQuantSpec, NVFP4, MXFP4, fake_quant,
                                 PackedQuantizedTensor)

# the six quantization points
POINTS = ("fwd_w", "fwd_a", "bwd_w", "bwd_g", "upd_g", "upd_a")
# the paper's selective-rounding scheme (eqs. 4-6): SR on neural gradients in
# backward+update GEMMs and on activations in the update GEMM.
PAPER_SR_POINTS: FrozenSet[str] = frozenset({"bwd_g", "upd_g", "upd_a"})


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Which BlockQuantSpec (or None = keep bf16) applies at each GEMM point."""

    fwd_w: Optional[BlockQuantSpec] = None
    fwd_a: Optional[BlockQuantSpec] = None
    bwd_w: Optional[BlockQuantSpec] = None
    bwd_g: Optional[BlockQuantSpec] = None
    upd_g: Optional[BlockQuantSpec] = None
    upd_a: Optional[BlockQuantSpec] = None
    # "jnp" (fake-quant reference path) or "pallas" (fused TPU kernels)
    impl: str = "jnp"

    @property
    def enabled(self) -> bool:
        return any(getattr(self, p) is not None for p in POINTS)

    def spec(self, point: str) -> Optional[BlockQuantSpec]:
        return getattr(self, point)

    def replace(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


# ---- presets (paper Table 2 + sweeps) ----------------------------------------


def bf16_config() -> QuantConfig:
    """BF16 baseline: no quantization anywhere."""
    return QuantConfig()


def fqt_config(base: BlockQuantSpec = NVFP4,
               sr_points: FrozenSet[str] = PAPER_SR_POINTS,
               impl: str = "jnp") -> QuantConfig:
    """Full FQT of all six points; ``sr_points`` use SR, the rest RtN."""
    kw = {p: base.with_rounding(stochastic=(p in sr_points)) for p in POINTS}
    return QuantConfig(impl=impl, **kw)


def nvfp4_paper_config(impl: str = "jnp") -> QuantConfig:
    """The paper's scheme: NVFP4 everywhere, split rounding (eqs. 4-6)."""
    return fqt_config(NVFP4, PAPER_SR_POINTS, impl)


def mxfp4_config(impl: str = "jnp") -> QuantConfig:
    return fqt_config(MXFP4, PAPER_SR_POINTS, impl)


def qaf_config(impl: str = "jnp") -> QuantConfig:
    """Quantization-aware finetuning: FP4 forward, BF16 backward+update."""
    return QuantConfig(fwd_w=NVFP4, fwd_a=NVFP4, impl=impl)


def wang2025_config() -> QuantConfig:
    """[21] Wang et al.: FP4 weights+activations (forward only), BF16 grads."""
    return QuantConfig(fwd_w=NVFP4, fwd_a=NVFP4, bwd_w=NVFP4)


def tseng2025_config() -> QuantConfig:
    """[19] Tseng et al.: MXFP4+SR neural gradients only, BF16 W/A."""
    sr = MXFP4.with_rounding(stochastic=True)
    return QuantConfig(bwd_g=sr, upd_g=sr)


# ---- seed plumbing -----------------------------------------------------------


def _site_seed32(seed: jax.Array, site: int) -> jax.Array:
    """Per-quantization-site 32-bit counter seed from the layer/step seed."""
    return (jnp.asarray(seed, jnp.uint32) * jnp.uint32(0x9E3779B1)
            ^ jnp.uint32((site * 0x7FB5D329) & 0xFFFFFFFF))


def _site_bits(x_shape, seed: jax.Array, site: int) -> jax.Array:
    """SR random bits for a site — counter-based (formats.counter_bits), so
    the jnp path fuses them into the quantize chain (zero HBM traffic) and
    the Pallas path receives the *identical* stream as an operand."""
    from repro.core import formats
    return formats.counter_bits(_site_seed32(seed, site), x_shape)


def _site_u(seed: jax.Array, site: int, shape) -> jax.Array:
    from repro.core import formats
    return formats.uniform_from_bits(_site_bits(shape, seed, site))


def _maybe_q(x: jax.Array, spec: Optional[BlockQuantSpec], axis: int,
             seed: jax.Array, site: int) -> jax.Array:
    if spec is None:
        return x
    u = _site_u(seed, site, x.shape) if spec.stochastic else None
    return fake_quant(x, spec, axis=axis, u=u)


def _pallas_gemm(a2d, b2d, spec_a, spec_b, seed, site_a, site_b, out_dtype,
                 rb_a=None, rb_b=None):
    """One fused quantize+matmul Pallas call (blocks: a axis1, b axis0)."""
    from repro.kernels import ops as kops
    if spec_a is not None and spec_a.stochastic and rb_a is None:
        rb_a = _site_bits(a2d.shape, seed, site_a)
    if spec_b is not None and spec_b.stochastic and rb_b is None:
        rb_b = _site_bits(b2d.shape, seed, site_b)
    return kops.fused_quant_matmul(a2d, b2d, spec_a, spec_b, a_rbits=rb_a,
                                   b_rbits=rb_b, out_dtype=out_dtype)


def _float0_zero(x: jax.Array):
    """Zero cotangent for an integer-dtype primal (tangent dtype float0)."""
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# ---- the FQT matmul ----------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fp4_matmul(x: jax.Array, w: jax.Array, seed: jax.Array,
                cfg: QuantConfig) -> jax.Array:
    return _forward(x, w, seed, cfg)


def _use_pallas(cfg, spec_a, spec_b, k_dim) -> bool:
    return (cfg.impl == "pallas" and spec_a is not None and spec_b is not None
            and spec_a.block == spec_b.block and k_dim % spec_a.block == 0)


def _if_divisible(spec: Optional[BlockQuantSpec], dim: int):
    """Quantization applies only when the contraction dim is block-divisible;
    otherwise that GEMM falls back to bf16 (hardware would pad — irregular
    dims only occur in reduced smoke configs, never in the real arch configs,
    which are all multiples of 16)."""
    if spec is not None and dim % spec.block != 0:
        return None
    return spec


def _forward(x, w, seed, cfg):
    """[Forward] z = Q_rtn(a) @ Q_rtn(W); blocks along K for both operands."""
    K, N = w.shape
    fwd_a = _if_divisible(cfg.fwd_a, K)
    fwd_w = _if_divisible(cfg.fwd_w, K)
    if _use_pallas(cfg, fwd_a, fwd_w, K):
        x2 = x.reshape(-1, K)
        y = _pallas_gemm(x2, w, fwd_a, fwd_w, seed, 0, 1, x.dtype)
        return y.reshape(x.shape[:-1] + (N,))
    qx = _maybe_q(x, fwd_a, axis=-1, seed=seed, site=0)
    qw = _maybe_q(w, fwd_w, axis=0, seed=seed, site=1)
    y = jnp.matmul(qx, qw, preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def _fwd_rule(x, w, seed, cfg):
    return _forward(x, w, seed, cfg), (x, w, seed)


def _bwd_rule(cfg, res, g):
    x, w, seed = res
    K, N = w.shape
    g32 = g

    # [Backward] dX = Q_sr(g) @ Q_rtn(W)^T ; contraction over N.
    bwd_g = _if_divisible(cfg.bwd_g, N)
    bwd_w = _if_divisible(cfg.bwd_w, N)
    if _use_pallas(cfg, bwd_g, bwd_w, N):
        g2 = g32.reshape(-1, N)
        dx = _pallas_gemm(g2, w.T, bwd_g, bwd_w, seed, 2, 3, x.dtype)
        dx = dx.reshape(x.shape)
    else:
        qg_b = _maybe_q(g32, bwd_g, axis=-1, seed=seed, site=2)
        qw_b = _maybe_q(w, bwd_w, axis=1, seed=seed, site=3)  # blocks on N
        dx = jnp.matmul(qg_b, qw_b.T,
                        preferred_element_type=jnp.float32).astype(x.dtype)

    # [Update] dW = Q_sr(a)^T @ Q_sr(g) ; contraction over tokens M.
    xf = x.reshape(-1, K)
    gf = g32.reshape(-1, N)
    M = xf.shape[0]
    upd_a, upd_g = cfg.upd_a, cfg.upd_g
    # Token count not divisible by the block (e.g. tiny eval batches): the
    # update GEMM falls back to bf16 rather than changing blocking semantics.
    if upd_a is not None and M % upd_a.block != 0:
        upd_a = None
    if upd_g is not None and M % upd_g.block != 0:
        upd_g = None
    if (_use_pallas(cfg, upd_a, upd_g, M) and upd_a is not None):
        rb_a = (_site_bits((M, K), seed, 4).T
                if upd_a.stochastic else None)           # align with jnp path
        dw = _pallas_gemm(xf.T, gf, upd_a, upd_g, seed, 4, 5, w.dtype,
                          rb_a=rb_a)
    else:
        qx_u = _maybe_q(xf, upd_a, axis=0, seed=seed, site=4)
        qg_u = _maybe_q(gf, upd_g, axis=0, seed=seed, site=5)
        dw = jnp.matmul(qx_u.T, qg_u,
                        preferred_element_type=jnp.float32).astype(w.dtype)

    return dx, dw, _float0_zero(res[2])


_fp4_matmul.defvjp(_fwd_rule, _bwd_rule)


# ---- pre-quantized (packed) weights: the quantize-once serving path ----------


def _packed_forward(x: jax.Array, w: PackedQuantizedTensor, seed: jax.Array,
                    cfg: QuantConfig) -> jax.Array:
    """[Forward] z = Q_rtn(a) @ dequant(w_packed): the weight was quantized
    ONCE (Engine init / checkpoint export) so only the activation is
    quantized per GEMM.  Bit-identical to ``_forward`` with ``fwd_w`` set —
    ``PackedQuantizedTensor.dequant`` reconstructs exactly the fake-quant
    grid values.  Inference-only (no custom_vjp; serving never backprops).
    """
    K, N = w.shape
    fwd_a = _if_divisible(cfg.fwd_a, K)
    if (cfg.impl == "pallas" and fwd_a is not None and w.axis == -2
            and fwd_a.block == w.block):
        from repro.kernels import ops as kops
        rb = (_site_bits(x.shape, seed, 0).reshape(-1, K)
              if fwd_a.stochastic else None)
        x2 = x.reshape(-1, K)
        y = kops.packed_block_matmul(x2, w, fwd_a, a_rbits=rb,
                                     out_dtype=x.dtype)
        return y.reshape(x.shape[:-1] + (N,))
    qx = _maybe_q(x, fwd_a, axis=-1, seed=seed, site=0)
    y = jnp.matmul(qx, w.dequant(), preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def fp4_matmul(x: jax.Array, w: jax.Array, *, cfg: QuantConfig,
               seed: Optional[jax.Array] = None) -> jax.Array:
    """FQT matmul  (..., K) @ (K, N) -> (..., N)  per the paper's scheme.

    ``seed``: uint32/int32 scalar controlling SR draws (required if any point
    uses stochastic rounding; derive per layer+step via ``jax.random.fold_in``
    semantics on an integer counter).
    """
    if w.ndim != 2:
        raise ValueError(f"weight must be 2D, got {w.shape}")
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    if seed is None:
        seed = jnp.zeros((), jnp.uint32)
    if isinstance(w, PackedQuantizedTensor):
        return _packed_forward(x, w, jnp.asarray(seed, jnp.uint32), cfg)
    if not cfg.enabled:
        return jnp.matmul(x, w,
                          preferred_element_type=jnp.float32).astype(x.dtype)
    return _fp4_matmul(x, w, jnp.asarray(seed, jnp.uint32), cfg)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None, *,
          cfg: QuantConfig, seed: Optional[jax.Array] = None) -> jax.Array:
    """Linear layer through the FQT matmul (bias added in bf16)."""
    y = fp4_matmul(x, w, cfg=cfg, seed=seed)
    if b is not None:
        y = y + b
    return y
