"""Generic low-precision floating-point formats and quantizers.

This module is the numeric foundation of the FP4-FQT framework. It defines
``FloatFormat`` — a generic (sign, exp_bits, man_bits) minifloat description —
and grid-exact round-to-nearest-even (RtN) and stochastic-rounding (SR)
quantizers that work for any such format.

Conventions (see DESIGN.md §4):
  * E2M1 (FP4 data):  no NaN/Inf, saturating, max 6.0 — matches
    ``ml_dtypes.float4_e2m1fn``.
  * E4M3 (NVFP4 scale): OCP e4m3fn, max 448 — matches
    ``ml_dtypes.float8_e4m3fn``.
  * E8M0 (MXFP4 scale): unsigned exponent-only — matches
    ``ml_dtypes.float8_e8m0fnu``; block scales use the OCP MX
    floor(log2(amax)) − emax rule (see quantize.py).
  * Sweep formats E1M6..E6M1: our no-NaN convention,
    max = 2^emax * (2 - 2^-M).

All quantizers are pure jnp and jit/vmap/grad-safe (they are used inside
custom_vjp rules).  RtN uses round-half-to-even.  SR is *grid exact*: the
output is always one of the two representable neighbours and
E[Q_SR(x)] == x for in-range x.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """A generic signed/unsigned minifloat format with subnormals."""

    name: str
    exp_bits: int
    man_bits: int
    signed: bool = True
    # Exponent bias.  None => IEEE-style default 2^(E-1) - 1.
    bias: Optional[int] = None
    # Maximum finite value.  None => no-NaN convention 2^emax * (2 - 2^-M).
    finite_max: Optional[float] = None

    # ---- derived quantities -------------------------------------------------

    @property
    def ebias(self) -> int:
        if self.bias is not None:
            return self.bias
        return (1 << (self.exp_bits - 1)) - 1 if self.exp_bits > 0 else 0

    @property
    def emax(self) -> int:
        """Largest normal exponent (of the leading bit)."""
        if self.finite_max is not None:
            return int(np.floor(np.log2(self.finite_max)))
        return (1 << self.exp_bits) - 1 - self.ebias

    @property
    def emin(self) -> int:
        """Smallest normal exponent; subnormal ulp is 2^(emin - man_bits)."""
        return 1 - self.ebias

    @property
    def max(self) -> float:
        if self.finite_max is not None:
            return self.finite_max
        return float(2.0 ** self.emax * (2.0 - 2.0 ** (-self.man_bits)))

    @property
    def smallest_subnormal(self) -> float:
        if self.man_bits == 0:
            return float(2.0 ** self.emin)
        return float(2.0 ** (self.emin - self.man_bits))

    @property
    def nbits(self) -> int:
        return int(self.signed) + self.exp_bits + self.man_bits

    def grid(self) -> np.ndarray:
        """All non-negative representable values, ascending (numpy)."""
        vals = [0.0]
        for e in range(self.emin, self.emax + 1):
            for m in range(1 << self.man_bits):
                frac = 1.0 + m / (1 << self.man_bits)
                vals.append(frac * 2.0 ** e)
        # subnormals
        for m in range(1, 1 << self.man_bits):
            vals.append((m / (1 << self.man_bits)) * 2.0 ** self.emin)
        vals = sorted(set(v for v in vals if v <= self.max + 1e-30))
        return np.asarray(vals, dtype=np.float64)


# ---- canonical formats -------------------------------------------------------

E2M1 = FloatFormat("e2m1", exp_bits=2, man_bits=1, finite_max=6.0)
E4M3 = FloatFormat("e4m3", exp_bits=4, man_bits=3, finite_max=448.0)
E5M2 = FloatFormat("e5m2", exp_bits=5, man_bits=2, finite_max=57344.0)
E8M0 = FloatFormat("e8m0", exp_bits=8, man_bits=0, signed=False,
                   finite_max=float(2.0 ** 127))
BF16 = FloatFormat("bf16", exp_bits=8, man_bits=7, finite_max=float(
    2.0 ** 127 * (2.0 - 2.0 ** -7)))

# Scale-format sweep of paper Fig. 1 (8-bit budget, sign bit unused except E8M0)
E1M6 = FloatFormat("e1m6", exp_bits=1, man_bits=6)
E2M5 = FloatFormat("e2m5", exp_bits=2, man_bits=5)
# IEEE-style like ml_dtypes.float8_e3m4 (top exponent code reserved): max 15.5
E3M4 = FloatFormat("e3m4", exp_bits=3, man_bits=4, finite_max=15.5)
E6M1 = FloatFormat("e6m1", exp_bits=6, man_bits=1)

SCALE_FORMATS = {
    "e1m6": E1M6, "e2m5": E2M5, "e3m4": E3M4, "e4m3": E4M3,
    "e5m2": E5M2, "e6m1": E6M1, "e8m0": E8M0,
}

FORMATS = dict(SCALE_FORMATS, e2m1=E2M1, bf16=BF16)


def get_format(name: str) -> FloatFormat:
    try:
        return FORMATS[name.lower()]
    except KeyError:
        raise ValueError(f"unknown float format {name!r}; have {sorted(FORMATS)}")


# ---- core grid math ----------------------------------------------------------


def _ulp(absx: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Spacing of the representable grid at |x| (for in-range |x|).

    For absx in [2^e, 2^(e+1)) with e in [emin, emax], the ulp is
    2^(e - man_bits); below 2^emin the (subnormal) ulp is 2^(emin - man_bits).
    Exact powers of two belong to the *upper* binade per frexp, which yields
    the correct ulp for both RtN and floor-based SR.
    """
    # frexp: absx = m * 2^k with m in [0.5, 1)  =>  floor(log2 absx) = k - 1.
    # NOTE: jnp.exp2 is *inexact* on the CPU backend (exp2(13.)=8192.004), so
    # all power-of-two math here uses ldexp, which is exact.
    _, k = jnp.frexp(absx)
    e = jnp.clip(k - 1, fmt.emin, fmt.emax)
    return jnp.ldexp(jnp.ones((), absx.dtype), e - fmt.man_bits)


def quantize_rtn(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Round-to-nearest-even onto fmt's grid, saturating at fmt.max.

    Returns values of x.dtype that lie exactly on the format grid.
    """
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    sign = jnp.sign(x)
    absx = jnp.minimum(jnp.abs(x), fmt.max)
    ulp = _ulp(absx, fmt)
    # round-half-to-even on the integer lattice absx/ulp
    q = jnp.round(absx / ulp) * ulp
    # Rounding up at a binade boundary can overshoot fmt.max (e.g. 5.9 -> 6 ok,
    # but for fn formats with truncated top binade, e.g. e4m3 464 -> 480>448).
    q = jnp.minimum(q, fmt.max)
    out = sign * q
    if not fmt.signed:
        out = jnp.maximum(out, 0.0)
    return out.astype(orig_dtype)


def quantize_sr_with_u(x: jax.Array, fmt: FloatFormat,
                       u: jax.Array) -> jax.Array:
    """Stochastic rounding with explicit uniforms u in [0, 1) (same shape as
    x).  Grid-exact and unbiased in-range:  floor(|x|/ulp + u) * ulp.

    This is the exact semantics the Pallas kernels implement, so it doubles
    as their oracle.  Saturates at fmt.max (tail clipping is the only bias
    source, as in hardware SR).
    """
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    sign = jnp.sign(x)
    absx = jnp.minimum(jnp.abs(x), fmt.max)
    ulp = _ulp(absx, fmt)
    q = jnp.floor(absx / ulp + u) * ulp
    q = jnp.minimum(q, fmt.max)
    out = sign * q
    if not fmt.signed:
        out = jnp.maximum(out, 0.0)
    return out.astype(orig_dtype)


def uniform_from_bits(rbits: jax.Array) -> jax.Array:
    """uint32 random bits -> uniform [0, 1) float32 (24-bit resolution).

    Shared convention between the Pallas kernels (which consume raw
    counter-based bits) and the jnp oracles.
    """
    return (rbits >> 8).astype(jnp.float32) * jnp.float32(2.0 ** -24)


def quantize_sr(x: jax.Array, fmt: FloatFormat, key: jax.Array) -> jax.Array:
    """Stochastic rounding onto fmt's grid using a JAX PRNG key."""
    rbits = jax.random.bits(key, shape=x.shape, dtype=jnp.uint32)
    return quantize_sr_with_u(x, fmt, uniform_from_bits(rbits))


def counter_bits(seed: jax.Array, shape) -> jax.Array:
    """Counter-based random bits that FUSE into their consumer.

    splitmix32-style avalanche hash of (seed, flat index): ~7 elementwise
    ops that XLA fuses straight into the quantization fusion — zero extra
    HBM traffic.  jax.random.bits (threefry) materializes the u32 tensor
    through ~20 unfusable rolled ops; at FQT scale that was ~3 TB/device/
    step of pure RNG traffic (EXPERIMENTS.md §Perf iteration 2).  SR needs
    24 decorrelated uniform bits per element, not crypto — avalanche
    quality is sufficient and is validated by the same unbiasedness tests.
    Deterministic in (seed, index): replayable after checkpoint restart.
    """
    n = 1
    for d in shape:
        n *= int(d)
    idx = jax.lax.iota(jnp.uint32, n).reshape(shape)
    z = idx * jnp.uint32(0x9E3779B9) + jnp.asarray(seed, jnp.uint32)
    z = (z ^ (z >> 16)) * jnp.uint32(0x85EBCA6B)
    z = (z ^ (z >> 13)) * jnp.uint32(0xC2B2AE35)
    z = z ^ (z >> 16)
    # second mix round decorrelates consecutive indices fully
    z = (z + jnp.uint32(0x9E3779B9))
    z = (z ^ (z >> 15)) * jnp.uint32(0x2C1B3C6D)
    z = (z ^ (z >> 12)) * jnp.uint32(0x297A2D39)
    return z ^ (z >> 15)


def quantize(x: jax.Array, fmt: FloatFormat, *, stochastic: bool = False,
             key: Optional[jax.Array] = None) -> jax.Array:
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        return quantize_sr(x, fmt, key)
    return quantize_rtn(x, fmt)


# ---- E8M0 power-of-two helpers (OCP MX scale rule) ---------------------------


def e8m0_floor(x: jax.Array) -> jax.Array:
    """Largest power of two <= x (x > 0), clipped to E8M0 range."""
    x = x.astype(jnp.float32)
    _, k = jnp.frexp(x)
    e = jnp.clip(k - 1, -127, 127)
    return jnp.ldexp(jnp.ones((), jnp.float32), e)


@lru_cache(maxsize=None)
def _grid_device(fmt: FloatFormat):
    return jnp.asarray(fmt.grid(), dtype=jnp.float32)


def snap_distance(x: np.ndarray, fmt: FloatFormat) -> np.ndarray:
    """Distance from each value of x to the nearest grid point (testing aid)."""
    g = fmt.grid()
    full = np.concatenate([-g[::-1], g]) if fmt.signed else g
    idx = np.clip(np.searchsorted(full, x), 1, len(full) - 1)
    lo, hi = full[idx - 1], full[idx]
    return np.minimum(np.abs(x - lo), np.abs(x - hi))
