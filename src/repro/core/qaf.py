"""Quantization-Aware Finetuning (QAF) phase orchestration (paper §5).

When FP4 pretraining stalls (the §4 threshold crosses √3, or a fixed token
budget is reached), training continues with the *forward* GEMMs still in FP4
— so the deployed model stays FP4-inference-compatible — while backward and
update GEMMs run in BF16, restoring the gradient signal-to-noise ratio.  The
LR is re-warmed (40 steps) and cosine-decayed from a reduced peak.
"""
from __future__ import annotations

import dataclasses

from repro.core import fqt
from repro.optim.schedule import ScheduleConfig, qaf_schedule


@dataclasses.dataclass(frozen=True)
class QAFConfig:
    enabled: bool = True
    auto_switch: bool = True        # switch on the §4 threshold crossing
    fixed_switch_step: int = 0      # >0: switch at this step regardless
    qaf_steps: int = 1000
    peak_scale: float = 0.5


def qaf_quant_config(pretrain_cfg: fqt.QuantConfig) -> fqt.QuantConfig:
    """FP4 forward / BF16 backward+update, preserving fwd specs + impl."""
    return fqt.QuantConfig(fwd_w=pretrain_cfg.fwd_w,
                           fwd_a=pretrain_cfg.fwd_a,
                           impl=pretrain_cfg.impl)


def qaf_lr_schedule(base: ScheduleConfig, cfg: QAFConfig,
                    start_step: int = 0) -> ScheduleConfig:
    return qaf_schedule(base, cfg.qaf_steps, cfg.peak_scale, start_step)


def should_switch(step: int, threshold_crossed: bool, cfg: QAFConfig) -> bool:
    if not cfg.enabled:
        return False
    if cfg.fixed_switch_step and step >= cfg.fixed_switch_step:
        return True
    return cfg.auto_switch and bool(threshold_crossed)
