"""LR schedules: linear warmup + cosine decay, and the paper's QAF re-warm
(reset LR, 40-iteration warmup, cosine decay from a fresh peak — §5)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # phase offset: the schedule is relative to this global step (the QAF
    # re-warm starts its fresh warmup+cosine at the switch step)
    start_step: int = 0


def lr_at(step, cfg: ScheduleConfig):
    """Warmup + cosine; step may be traced (relative to cfg.start_step)."""
    step = jnp.maximum(jnp.asarray(step, jnp.float32) - cfg.start_step, 0.0)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    mincoef = cfg.min_lr_ratio
    cos = cfg.peak_lr * (mincoef + (1 - mincoef)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def qaf_schedule(base: ScheduleConfig, qaf_steps: int,
                 peak_scale: float = 0.5,
                 start_step: int = 0) -> ScheduleConfig:
    """The paper's QAF phase: fresh 40-step warmup + cosine over the QAF
    budget, peak reset to a fraction of the pretrain peak."""
    return ScheduleConfig(peak_lr=base.peak_lr * peak_scale,
                          warmup_steps=min(40, max(qaf_steps // 4, 1)),
                          total_steps=qaf_steps,
                          min_lr_ratio=0.0, start_step=start_step)
