"""AdamW with FP32 master weights — the FQT training optimizer.

The paper trains bf16 compute weights with a high-precision optimizer
(standard FP8/FP4-FQT practice): the *forward* weights are bf16 (quantized to
FP4 per GEMM), while the optimizer keeps FP32 master weights + moments and
re-casts after each update.  Moment dtype is configurable (bf16 moments for
the 405B memory budget — DESIGN.md §6).

Implemented from scratch (no optax in this environment): pure-pytree,
jit/pjit-friendly, with global-norm clipping and decoupled weight decay.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32     # bf16 for very large models
    master_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    master: Any        # fp32 master weights (pytree like params)
    m: Any
    v: Any


def init(params, cfg: AdamWConfig) -> AdamWState:
    master = jax.tree.map(lambda p: p.astype(cfg.master_dtype), params)
    m = jax.tree.map(lambda p: jnp.zeros_like(p, cfg.moment_dtype), params)
    v = jax.tree.map(lambda p: jnp.zeros_like(p, cfg.moment_dtype), params)
    return AdamWState(jnp.zeros((), jnp.int32), master, m, v)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def apply(grads, state: AdamWState, cfg: AdamWConfig, lr: jax.Array):
    """One AdamW step.  Returns (new_bf16_params, new_state, metrics)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1, b2 = cfg.betas
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, master, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * \
            master.astype(jnp.float32)
        new_master = master.astype(jnp.float32) - lr * delta
        return (new_master.astype(cfg.master_dtype),
                m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype))

    out = jax.tree.map(upd, grads, state.master, state.m, state.v)
    new_master = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    # compute-weight dtype follows the original param dtype (bf16 weights,
    # f32 for the few full-precision leaves like SSM A_log / gate biases)
    new_params = jax.tree.map(lambda mw, g: mw.astype(g.dtype),
                              new_master, grads)
    return new_params, AdamWState(step, new_master, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
