"""Sharded, atomic, reshardable checkpoints (no external deps).

Layout:  <dir>/step_<N>/
           meta.json               step, pytree structure, shapes/dtypes
           shard_<i>.npz           flat leaves (this host's slice)
         <dir>/LATEST              atomic pointer file

Properties required at scale (DESIGN.md §6):
  * atomic: written to step_<N>.tmp then os.replace'd; LATEST updated last —
    a crash mid-save never corrupts the restore point.
  * restart-safe: ``restore_latest`` + the step-indexed data pipeline resume
    exactly.
  * elastic: arrays are saved unsharded-logical (gathered per leaf); on
    restore they are placed under *whatever sharding the new mesh dictates*,
    so a job can restart on a different topology (tested in
    tests/test_checkpoint.py).
  * packed serving artifacts: a params tree packed with
    ``serve.packing.pack_model_params`` saves/restores through the same
    ``save``/``restore`` API (PackedQuantizedTensor is a registered pytree;
    uint8 nibble codes and float8 scales round-trip via _VIEW_DTYPES), so
    the exported serving checkpoint is 4-bit on disk.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Dtypes np.savez cannot round-trip natively (it degrades them to void):
# stored as a same-width unsigned-int view, dtype name recorded in meta.
# Covers bf16 params and the packed-NVFP4 serving store (float8 block
# scales ride next to uint8 nibble codes, keeping exported artifacts
# 4-bit on disk).
_VIEW_DTYPES = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.isdir(final):             # idempotent re-save of a step
        return final
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    arrays = {}
    meta_leaves = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        name = str(arr.dtype)
        if name in _VIEW_DTYPES:
            arrays[f"leaf_{i}"] = arr.view(_VIEW_DTYPES[name])
            meta_leaves.append({"dtype": name})
        else:
            arrays[f"leaf_{i}"] = arr
            meta_leaves.append({"dtype": name})
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves),
                   "leaves": meta_leaves,
                   "treedef": str(treedef)}, f)
    os.replace(tmp, final)
    # update LATEST atomically
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, step: int, tree_like, *, shardings=None):
    """Restore into the structure of ``tree_like``; optionally place leaves
    with ``shardings`` (pytree of NamedSharding) — the elastic-resharding
    path: the saved arrays are logical (unsharded), so any new mesh works."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(d, "shard_0.npz"))
    leaves, treedef = _flatten(tree_like)
    if meta["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, target structure "
            f"has {len(leaves)} — architecture mismatch")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    out = []
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"leaf_{i}"]
        dt = meta["leaves"][i]["dtype"]
        if dt in _VIEW_DTYPES:
            arr = arr.view(jnp.dtype(dt))
        if tuple(arr.shape) != tuple(np.shape(ref)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {np.shape(ref)}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def restore_latest(ckpt_dir: str, tree_like, *, shardings=None):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return step, restore(ckpt_dir, step, tree_like, shardings=shardings)
