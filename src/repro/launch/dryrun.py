import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**abstract_inputs).compile()`` must succeed
on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh for every
assigned architecture × input shape.  ``memory_analysis()`` proves the
sharded program fits; ``cost_analysis()`` + the optimized HLO feed the
§Roofline terms.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
  python -m repro.launch.dryrun ... --out results/dryrun     # JSON per cell

(The XLA_FLAGS line above MUST precede any jax import: jax locks the device
count on first init.  Only this entry point forces 512 host devices —
tests/benches see the real single CPU device.)
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.core import fqt
from repro.launch import roofline as rl
from repro.launch import specs as specs_mod
from repro.launch.mesh import describe, make_production_mesh
from repro.models.config import SHAPES, SHAPES_BY_NAME


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             qcfg_name: str = "nvfp4", act_mode: str | None = "sp",
             cfg_overrides: dict | None = None,
             verbose: bool = True, extra: dict | None = None) -> dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES_BY_NAME[shape_name]
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "qcfg": qcfg_name, "kind": shape.kind, "act_mode": act_mode}

    reason = specs_mod.skip_reason(cfg, shape)
    if reason:
        rec.update(status="skip", reason=reason)
        return rec

    qcfg = {
        "nvfp4": fqt.nvfp4_paper_config,
        "bf16": fqt.bf16_config,
        "qaf": fqt.qaf_config,
        "mxfp4": fqt.mxfp4_config,
    }[qcfg_name]()

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        cell = specs_mod.build_cell(cfg, shape, mesh, qcfg=qcfg)
        cell.act_mode = act_mode
        lowered = specs_mod.lower_cell(cell, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:                                    # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return rec

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    mf = rl.model_flops(cfg, specs_mod.params_struct(cfg), shape)
    roof = rl.from_compiled(compiled, hlo, chips, model_flops=mf)
    from repro.launch import hlo_cost
    hcost = hlo_cost.analyze(hlo)
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]

    rec.update(
        status="ok", mesh=describe(mesh), chips=chips,
        t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
        bytes_per_device={
            "argument": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "peak": (getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "temp_size_in_bytes", 0)),
        },
        roofline=roof.as_dict(),
        collectives={k: v for k, v in hcost.coll.items() if v},
        eltflops=hcost.eltflops,
        xla_cost_once={"flops": float(xla_cost.get("flops", 0)),
                       "bytes": float(xla_cost.get("bytes accessed", 0))},
    )
    if extra:
        rec.update(extra)
    if verbose:
        print(f"[{arch} × {shape_name} × {describe(mesh)}] "
              f"compile {t_compile:.0f}s  "
              f"compute {roof.t_compute*1e3:.2f}ms  "
              f"memory {roof.t_memory*1e3:.2f}ms  "
              f"collective {roof.t_collective*1e3:.2f}ms  "
              f"-> {roof.bottleneck}-bound; "
              f"useful {100*(roof.useful_fraction or 0):.0f}%  "
              f"temp/dev {(rec['bytes_per_device']['temp'] or 0)/2**30:.2f}GiB")
        sys.stdout.flush()
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--qcfg", default="nvfp4",
                    choices=["nvfp4", "bf16", "qaf", "mxfp4"])
    ap.add_argument("--act-mode", default="sp",
                    choices=["sp", "replicated", "off"],
                    help="activation-constraint mode (§Perf ablation)")
    ap.add_argument("--out", default=None, help="directory for JSON records")
    args = ap.parse_args(argv)
    act_mode = None if args.act_mode == "off" else args.act_mode

    archs = [a for a in ARCH_IDS if not a.startswith("llama2")] \
        if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else [args.shape]

    failures = 0
    for arch in archs:
        for shape in shapes:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           qcfg_name=args.qcfg, act_mode=act_mode)
            if rec["status"] == "error":
                failures += 1
                print(f"[{arch} × {shape}] FAILED: {rec['error']}")
            elif rec["status"] == "skip":
                print(f"[{arch} × {shape}] SKIP: {rec['reason']}")
            if args.out:
                os.makedirs(args.out, exist_ok=True)
                pod = "2pod" if args.multi_pod else "1pod"
                path = os.path.join(
                    args.out,
                    f"{rec['arch']}__{rec['shape']}__{pod}__{args.qcfg}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
