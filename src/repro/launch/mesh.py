"""Production meshes.

A TPU v5e pod is 16×16 = 256 chips; the production job is 2 pods = 512.
Axes: "data" carries DP+FSDP, "model" carries TP(+SP); the optional outer
"pod" axis is pure DP whose gradient all-reduce crosses the inter-pod links
(and is where distributed/compression.py applies).

``make_production_mesh`` is a *function* so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (tests / examples): (1, n) data×model."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_serve_mesh(spec=None):
    """Serving mesh from a ``--mesh`` CLI spec ("tp=2", "dp=2,tp=4", None
    = 1-device).  Thin re-export of ``distributed.sharding.make_serve_mesh``
    so launchers take meshes from one module."""
    from repro.distributed.sharding import make_serve_mesh as f
    return f(spec)


def describe(mesh) -> str:
    return f"mesh{dict(zip(mesh.axis_names, mesh.devices.shape))}"
