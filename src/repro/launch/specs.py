"""ShapeDtypeStruct input specs + step builders for every dry-run cell.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStructs for
every model input of that (architecture × input-shape) cell — no device
allocation anywhere (states/params come from ``jax.eval_shape``).

Cell kinds (assignment):
  train_*    -> train_step   (loss + grads + AdamW + §4 monitor)
  prefill_*  -> prefill_step (full-sequence forward, logits)
  decode_* / long_*
             -> serve_step   (ONE new token against a seq_len-deep cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import fqt
from repro.distributed import sharding as shd
from repro.models import registry
from repro.models.config import ModelConfig, ShapeConfig, SHAPES_BY_NAME
from repro.serve.engine import serve_step_fn
from repro.train import step as step_mod


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """DESIGN.md §Arch-applicability skip rules."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("full quadratic attention cannot run 500k-token decode "
                "(no sub-quadratic path in this arch family)")
    return None


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(  # shape-only: the key value never materializes
        lambda: registry.init_params(cfg, jax.random.PRNGKey(0)))  # fp4lint: disable=prng-reuse


def train_state_struct(cfg: ModelConfig, tcfg: step_mod.TrainConfig):
    return jax.eval_shape(  # shape-only: the key value never materializes
        lambda: step_mod.init_state(cfg, tcfg, jax.random.PRNGKey(0)))  # fp4lint: disable=prng-reuse


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                               jnp.bfloat16)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def decode_carry_struct(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: registry.make_decode_state(cfg, shape.global_batch,
                                           shape.seq_len))


@dataclasses.dataclass
class Cell:
    """One dry-run cell: a jittable fn + abstract args + shardings."""
    kind: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Any
    donate: Tuple[int, ...] = ()
    act_mode: Optional[str] = "sp"   # activation-constraint mode (None=off)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               qcfg: Optional[fqt.QuantConfig] = None,
               tcfg: Optional[step_mod.TrainConfig] = None) -> Cell:
    qcfg = qcfg if qcfg is not None else fqt.nvfp4_paper_config()
    tcfg = tcfg if tcfg is not None else step_mod.TrainConfig()
    dp = shd.dp_axes(mesh)
    dp_size = 1
    for a, n in zip(mesh.axis_names, mesh.devices.shape):
        if a in dp:
            dp_size *= n

    if shape.kind == "train":
        state = train_state_struct(cfg, tcfg)
        batch = batch_struct(cfg, shape)
        st_sh = step_mod.state_shardings(state, mesh)
        b_sh = jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(dp, *(None,) * (len(x.shape) - 1))
                if x.shape[0] % dp_size == 0 else P()), batch)
        fn = step_mod.make_train_step(cfg, qcfg, tcfg, mesh)
        return Cell("train", fn, (state, batch), (st_sh, b_sh), donate=(0,))

    if shape.kind == "prefill":
        params = params_struct(cfg)
        batch = batch_struct(cfg, shape)
        batch["tokens"] = jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32)
        p_sh = shd.params_shardings(params, mesh)
        b_sh = jax.tree.map(
            lambda x: NamedSharding(
                mesh, P(dp, *(None,) * (len(x.shape) - 1))
                if x.shape[0] % dp_size == 0 else P()), batch)

        def prefill_step(params, batch):
            logits, _ = registry.forward(params, cfg, qcfg, batch,
                                         seed=0, remat=False)
            return logits

        return Cell("prefill", prefill_step, (params, batch), (p_sh, b_sh))

    # decode / long: one token against a full cache
    params = params_struct(cfg)
    carry = decode_carry_struct(cfg, shape)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    p_sh = shd.params_shardings(params, mesh)
    c_sh = shd.cache_specs(carry, mesh, shape.global_batch)
    t_sh = NamedSharding(
        mesh, P(dp) if shape.global_batch % dp_size == 0 else P())
    raw = serve_step_fn(cfg, qcfg)

    def serve_step(params, tokens, carry):
        return raw(params, tokens, carry)

    return Cell("decode", serve_step, (params, tokens, carry),
                (p_sh, t_sh, c_sh), donate=(2,))


def lower_cell(cell: Cell, mesh: Mesh):
    import contextlib
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate)
    scope = (shd.activation_sharding_scope(mesh, cell.act_mode)
             if cell.act_mode else contextlib.nullcontext())
    with mesh, scope:
        return jitted.lower(*cell.args)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]
