"""Trip-count-aware cost analysis of optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts every computation ONCE —
a ``lax.scan`` over 126 layers reports the FLOPs/bytes/collectives of a
single layer (measured on this build; see DESIGN.md §Roofline-method).  All
our models scan their layer stacks, so the built-in numbers undercount by
the trip count.  This module re-derives program cost from the optimized HLO
text, multiplying ``while`` bodies by their ``known_trip_count`` —
the roofline inputs then reflect what a device actually executes per step.

Counting model (per executed top-level op):
  * flops — MXU work: ``dot`` = 2 × prod(result) × prod(contracted dims)
    (batch dims handled; only dots/convolutions counted — elementwise VPU
    work is reported separately as ``eltflops`` for the quantize-overhead
    analysis).
  * bytes — HBM traffic under perfect fusion: Σ operand sizes + result
    size for every materializing op (fusion, dot, copy, slice, sort, ...);
    bookkeeping ops (tuple/gte/parameter/bitcast/constant) are free.
    Slicing reads (slice/dynamic-slice/gather — e.g. the per-layer weight
    slice inside a scanned stack) count the *sliced* size, not the full
    operand: a fusion operand that the fused computation only touches
    through slice/gather ops contributes the slice result size.
  * collective_bytes — result-shape bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (by kind).

The entry computation is walked with memoized recursion: ``while`` bodies
and conditions multiply by trip count, ``conditional`` takes the max branch,
fusions contribute their own operands/result only (their callees are
element-wise internals).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5, "s8": 1,
    "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "f8e8m0fnu": 1, "f4e2m1fn": 0.5, "c64": 8,
    "c128": 16, "token": 0, "s1": 0.125, "u1": 0.125,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that do not touch memory / are pure bookkeeping
_FREE_OPS = frozenset({
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "domain",
    "opt-barrier", "while", "conditional", "call",
})

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*"              # result name
    r"((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([a-z0-9_$.-]+)"                                 # op name
    r"\(([^)]*)\)")                                    # operand list
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([^\s,)]+)")
_COND_BODY_RE = re.compile(r"condition=%([^\s,)]+),\s*body=%([^\s,)]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([^\s,()]+)")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        bpe = _DTYPE_BYTES.get(dt)
        if bpe is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * bpe
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",")] if m.group(2) else []


def _shape_elems(type_str: str) -> float:
    n = 1
    for d in _shape_dims(type_str):
        n *= d
    return float(n)


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    op: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0         # MXU (dot/conv) flops
    eltflops: float = 0.0      # everything-else proxy (fusion result elems)
    bytes: float = 0.0         # HBM traffic upper bound (as-compiled fusion)
    bytes_min: float = 0.0     # lower bound: perfect fusion (dot/coll/DUS)
    coll: Optional[Dict[str, float]] = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in _COLLECTIVES}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.eltflops += other.eltflops * mult
        self.bytes += other.bytes * mult
        self.bytes_min += other.bytes_min * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _split_computations(text: str) -> Dict[str, Tuple[List[str], bool]]:
    """name -> (body lines, is_entry)."""
    comps: Dict[str, Tuple[List[str], bool]] = {}
    cur, cur_name, is_entry = None, None, False
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line and ("->" in line):
            m = re.match(r"\s*(ENTRY\s+)?%?([^\s(]+)\s*\(", line)
            if m:
                cur_name = m.group(2)
                is_entry = bool(m.group(1))
                cur = []
                comps[cur_name] = (cur, is_entry)
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            cur.append(line)
    return comps


def _dot_flops(op: _Op, shapes: Dict[str, str]) -> float:
    result = _shape_elems(op.type_str)
    m = _CONTRACT_RE.search(op.line)
    contracted = 1.0
    if m and op.operands:
        lhs_dims = _shape_dims(shapes.get(op.operands[0], ""))
        if m.group(1):
            for idx in m.group(1).split(","):
                i = int(idx)
                if i < len(lhs_dims):
                    contracted *= lhs_dims[i]
    return 2.0 * result * contracted


def top_ops(text: str, kinds=("all-gather", "all-reduce", "reduce-scatter",
                              "all-to-all", "collective-permute", "dot",
                              "fusion", "copy"), k: int = 25):
    """Rank ops by bytes × execution count (diagnostics for §Perf).

    Returns [(total_bytes, count, kind, result_type, metadata_op_name)].
    """
    comps = _split_computations(text)
    entry = next((n for n, (_, e) in comps.items() if e), None)
    # execution multiplier per computation, via the same while-walk
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    while order:
        cname = order.pop()
        m = mult.get(cname, 1.0)
        for line in comps.get(cname, ([], False))[0]:
            wm = _COND_BODY_RE.search(line)
            if wm and "while(" in line:
                trip = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = int(tm.group(1))
                for sub in (wm.group(1), wm.group(2)):
                    mult[sub] = mult.get(sub, 0.0) + m * trip
                    order.append(sub)
            cm = re.search(r"to_apply=%([^\s,)]+)", line)
            if cm and re.search(r"\bcall\(", line):
                mult[cm.group(1)] = mult.get(cm.group(1), 0.0) + m
                order.append(cm.group(1))
    rows = []
    for cname, (lines, _) in comps.items():
        m = mult.get(cname)
        if not m:
            continue
        for line in lines:
            om = _OP_RE.match(line)
            if not om:
                continue
            kind = om.group(3)
            base = kind[:-6] if kind.endswith("-start") else kind
            if base not in kinds or kind.endswith("-done"):
                continue
            nb = _shape_bytes(om.group(2)) * m
            meta = re.search(r'op_name="([^"]*)"', line)
            rows.append((nb, m, base, om.group(2)[:60],
                         (meta.group(1) if meta else "")[:110]))
    rows.sort(reverse=True)
    return rows[:k]


def analyze(text: str) -> Cost:
    comps = _split_computations(text)
    entry = next((n for n, (_, e) in comps.items() if e), None)
    if entry is None:
        return Cost()

    # first pass per computation: symbol table + op list
    parsed: Dict[str, List[_Op]] = {}
    shapes_by_comp: Dict[str, Dict[str, str]] = {}
    for name, (lines, _) in comps.items():
        ops, shapes = [], {}
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            opn = _Op(m.group(1), m.group(2), m.group(3),
                      _OPERAND_RE.findall(m.group(4)), line)
            ops.append(opn)
            shapes[opn.name] = opn.type_str
        parsed[name] = ops
        shapes_by_comp[name] = shapes

    _SLICE_OPS = ("dynamic-slice", "slice", "gather")

    def _sliced_params(cname: str) -> Dict[int, float]:
        """For a fused computation: parameter index -> sliced-read bytes,
        for parameters accessed ONLY via slice/dynamic-slice/gather."""
        ops = parsed.get(cname, [])
        param_idx: Dict[str, int] = {}
        uses: Dict[str, List[_Op]] = {}
        for op in ops:
            if op.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.line)
                if m:
                    param_idx[op.name] = int(m.group(1))
            for o in op.operands:
                uses.setdefault(o, []).append(op)
        out: Dict[int, float] = {}
        for pname, idx in param_idx.items():
            consumers = uses.get(pname, [])
            if consumers and all(
                    c.op in _SLICE_OPS and c.operands
                    and c.operands[0] == pname for c in consumers):
                out[idx] = max(_shape_bytes(c.type_str) for c in consumers)
        return out

    memo: Dict[str, Cost] = {}

    def comp_cost(cname: str, stack=()) -> Cost:
        if cname in memo:
            return memo[cname]
        if cname in stack or cname not in parsed:
            return Cost()
        total = Cost()
        shapes = shapes_by_comp[cname]
        for op in parsed[cname]:
            kind = op.op
            if kind == "while":
                trip = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trip = int(tm.group(1))
                cb = _COND_BODY_RE.search(op.line)
                if cb:
                    total.add(comp_cost(cb.group(2), stack + (cname,)), trip)
                    total.add(comp_cost(cb.group(1), stack + (cname,)), trip)
                continue
            if kind == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}",
                                      op.line) or \
                    re.findall(r"(?:true|false)_computation=%([^\s,)]+)",
                               op.line)
                names = []
                for b in branches:
                    names += [x.strip().lstrip("%") for x in b.split(",")]
                if names:
                    costs = [comp_cost(n, stack + (cname,)) for n in names]
                    best = max(costs, key=lambda c: (c.flops, c.bytes))
                    total.add(best)
                continue
            if kind == "call":
                cm = re.search(r"to_apply=%([^\s,)]+)", op.line)
                if cm:
                    total.add(comp_cost(cm.group(1), stack + (cname,)))
                continue
            # collectives
            base = kind[:-6] if kind.endswith("-start") else kind
            if base in _COLLECTIVES:
                if not kind.endswith("-done"):
                    total.coll[base] += _shape_bytes(op.type_str)
                    total.bytes += _shape_bytes(op.type_str)
                    total.bytes_min += _shape_bytes(op.type_str)
                continue
            if kind.endswith("-done"):
                continue
            if kind in _FREE_OPS:
                continue
            # memory traffic: operands + result (slice-aware)
            nbytes = _shape_bytes(op.type_str)
            if kind in _SLICE_OPS:
                # read the sliced region, not the source buffer
                nbytes += _shape_bytes(op.type_str)
                for o in op.operands[1:]:
                    nbytes += _shape_bytes(shapes.get(o, ""))
            elif kind == "dynamic-update-slice" and len(op.operands) >= 2:
                upd = _shape_bytes(shapes.get(op.operands[1], ""))
                nbytes = 2 * upd       # read+write the updated region
            else:
                sliced = {}
                if kind == "fusion":
                    cm = _CALLS_RE.search(op.line)
                    if cm:
                        sliced = _sliced_params(cm.group(1))
                for i, o in enumerate(op.operands):
                    nbytes += sliced.get(i, _shape_bytes(shapes.get(o, "")))
            total.bytes += nbytes
            if kind in ("dot", "convolution", "dynamic-update-slice",
                        "scatter", "sort"):
                total.bytes_min += nbytes
            if kind == "dot":
                total.flops += _dot_flops(op, shapes)
            elif kind == "convolution":
                # rough: 2 × result × (kernel elems) — fine, convs are rare
                total.flops += 2.0 * _shape_elems(op.type_str)
            else:
                total.eltflops += _shape_elems(op.type_str)
        memo[cname] = total
        return total

    return comp_cost(entry)
