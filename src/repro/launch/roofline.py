"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in *seconds* (assignment §Roofline):

  compute    = HLO_FLOPs        / (chips × peak_FLOP/s)
  memory     = HLO_bytes        / (chips × HBM_bw)
  collective = collective_bytes / (chips × link_bw)

``cost_analysis()`` provides FLOPs and bytes-accessed; collective bytes are
NOT in cost_analysis, so we parse the optimized HLO text and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.

UNIT CALIBRATION (measured on this jax/XLA-CPU build, see DESIGN.md): after
GSPMD partitioning, ``cost_analysis``/``memory_analysis``/``as_text`` all
describe the *single-device* SPMD program — i.e. they are already the
"/ chips" quantities of the formulas above.  We therefore divide by the
per-chip peaks only, and multiply FLOPs back by ``chips`` when comparing
against the global 6·N·D model-FLOPs estimate.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e per-chip constants
PEAK_FLOPS = 197e12         # bf16 MXU
HBM_BW = 819e9              # bytes/s
LINK_BW = 50e9              # bytes/s per ICI link (per-chip effective)

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 0.5, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = TYPE[...] op(...)`; async ops appear as op-start/op-done — count
# only `-start` (or the sync form) so nothing is double counted.
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", )
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes per collective kind over the whole module.

    For all-gather the result is the gathered (large) side, for
    reduce-scatter the operand is the large side — using the max of
    operand/result would need full operand tracking; the result size is the
    standard, slightly conservative proxy for wire bytes (each byte of an
    all-gather result crosses a link once in a ring).
    """
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(type_str)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                  # PER-DEVICE HLO FLOPs (see calibration)
    hbm_bytes: float              # per-device bytes accessed
    coll_bytes: float             # per-device collective wire bytes
    chips: int
    model_flops: Optional[float] = None   # GLOBAL 6·N·D (2·N·D inference)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time lower bound (perfectly overlapped)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        if not self.model_flops or not self.flops:
            return None
        return self.model_flops / (self.flops * self.chips)

    @property
    def mfu_bound(self) -> Optional[float]:
        """Best-case MFU = model FLOPs over peak at the roofline time."""
        if not self.model_flops:
            return None
        return self.model_flops / (self.chips * PEAK_FLOPS * self.t_bound)

    def as_dict(self) -> Dict[str, float]:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_fraction": self.useful_fraction,
            "mfu_bound": self.mfu_bound,
        }


def from_compiled(compiled, hlo_text: str, chips: int,
                  model_flops: Optional[float] = None) -> Roofline:
    """Roofline terms from the per-device optimized HLO.

    Uses the trip-count-aware pass (launch/hlo_cost.py) — XLA's built-in
    cost_analysis counts scan bodies once and is kept only as a cross-check
    field in the dry-run JSON.
    """
    from repro.launch import hlo_cost
    c = hlo_cost.analyze(hlo_text)
    return Roofline(flops=c.flops, hbm_bytes=c.bytes,
                    coll_bytes=c.coll_bytes, chips=chips,
                    model_flops=model_flops)


# ---- model-FLOPs accounting ----------------------------------------------------


def count_params(params_struct, active_expert_frac: float = 1.0,
                 expert_key: str = "w_") -> int:
    import jax
    return sum(int(x.size) for x in jax.tree.leaves(params_struct))


def model_flops(cfg, params_struct, shape) -> float:
    """6·N·D for training, 2·N·D for inference; N = active params for MoE."""
    import jax
    from jax.tree_util import tree_flatten_with_path

    total = 0
    expert = 0
    for path, leaf in tree_flatten_with_path(params_struct)[0]:
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        n = int(leaf.size)
        total += n
        if cfg.n_experts and re.search(r"(w_gate|w_up|w_down|smooth)", keys):
            expert += n
    n_active = total - expert + (expert * cfg.top_k // max(cfg.n_experts, 1)
                                 if cfg.n_experts else 0)
    tokens = shape.seq_len * shape.global_batch
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one new token per sequence (+ attention over the cache, which
    # is memory- not FLOP-dominated; excluded from the useful-FLOP count)
    return 2.0 * n_active * shape.global_batch
