"""Training launcher.

  python -m repro.launch.train --arch tinyllama-1.1b --smoke \\
      --steps 100 --quant nvfp4 --ckpt-dir /tmp/run1

On a real TPU cluster the same entry point runs under
``jax.distributed.initialize()`` with the production mesh; on this host it
runs the reduced config on the local device mesh.  Restart the same command
after a kill and it resumes from the latest checkpoint (the data pipeline
is step-indexed, so the token stream continues exactly).
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config
from repro.core import fqt, qaf
from repro.data.pipeline import DataConfig
from repro.optim import adamw, schedule
from repro.train import TrainConfig, Trainer, TrainerConfig


QUANT = {
    "nvfp4": fqt.nvfp4_paper_config,
    "mxfp4": fqt.mxfp4_config,
    "bf16": fqt.bf16_config,
    "qaf": fqt.qaf_config,
    "nvfp4_pallas": lambda: fqt.nvfp4_paper_config(impl="pallas"),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-350m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--quant", default="nvfp4", choices=sorted(QUANT))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=40)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--qaf-at", type=int, default=0,
                    help=">0: fixed-step QAF switch; 0: √3-threshold auto")
    ap.add_argument("--no-qaf", action="store_true")
    ap.add_argument("--log-json", default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="root PRNG seed (init + data stream)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a Chrome-trace-event JSON of quant-health "
                         "telemetry (optimizer-step clock: per-layer "
                         "√3-floor ratios, E4M3 scale saturation/underflow, "
                         "SR/RtN rounding tallies, one entry per log_every "
                         "steps) to PATH — open in Perfetto")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()

    tcfg = TrainConfig(
        opt=adamw.AdamWConfig(lr_peak=args.lr),
        sched=schedule.ScheduleConfig(peak_lr=args.lr,
                                      warmup_steps=args.warmup,
                                      total_steps=args.steps),
        remat=not args.smoke,
    )
    run_cfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        qaf=qaf.QAFConfig(enabled=not args.no_qaf,
                          auto_switch=args.qaf_at == 0,
                          fixed_switch_step=args.qaf_at),
    )
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(clock="step", process="train")

    trainer = Trainer(cfg, QUANT[args.quant](), tcfg, run_cfg, data_cfg,
                      tracer=tracer)
    trainer.run(jax.random.PRNGKey(args.seed))
    if tracer is not None:
        tracer.export(args.trace)
        print(f"trace: {tracer.n_events} events "
              f"(clock=step, every {run_cfg.log_every} steps) -> "
              f"{args.trace} (open in Perfetto: ui.perfetto.dev)")

    for h in trainer.history[:: max(1, len(trainer.history) // 20)]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnr {h['gnr']:.2f}  lr {h['lr']:.2e}  dt {h['dt']*1e3:.0f}ms")
    print("summary:", json.dumps(trainer.summary(), default=str)[:2000])
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump({"history": trainer.history,
                       "events": trainer.events}, f)


if __name__ == "__main__":
    main()
