"""Serving launcher: batched generation with the FP4 forward path.

  python -m repro.launch.serve --arch tinyllama-1.1b --smoke \\
      --batch 4 --max-new 32

  # continuous batching: 12 queued requests over 4 slots, 16-token pages
  python -m repro.launch.serve --arch tinyllama-1.1b --smoke \\
      --queue 12 --max-slots 4 --page-size 16

Initializes (or restores ``--ckpt-dir``) parameters, builds the Engine and
runs synthetic prompts through prefill + decode, reporting tokens/s.  With
``--queue`` the ContinuousEngine serves a staggered arrival trace through
the scheduler (admission queue, paged NVFP4 KV cache, slot reuse); without
it the lockstep Engine serves one static batch.  The forward GEMMs run in
NVFP4 RtN — the exact deployed numeric path the paper's QAF phase
preserves.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core import fqt
from repro.models import registry
from repro.serve import ContinuousEngine, Engine, Request, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-cache-format", default=None,
                    choices=("bf16", "nvfp4", "fp8"),
                    help="KV cache storage (nvfp4: 0.5625 bytes/elem; "
                         "bf16: unquantized escape hatch).  Default: nvfp4, "
                         "or bf16 when --bf16 is set")
    ap.add_argument("--bf16", action="store_true",
                    help="serve in bf16 instead of FP4 forward (also "
                         "defaults the KV cache to bf16)")
    ap.add_argument("--queue", type=int, default=0,
                    help="serve N queued requests through the continuous-"
                         "batching engine (staggered synthetic arrivals); "
                         "0 = lockstep batch")
    ap.add_argument("--max-slots", type=int, default=None,
                    help="continuous engine decode slots (default: --batch)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged cache pool)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: admit long prompts N tokens "
                         "per scheduler tick, interleaved with decode "
                         "(bit-exact; dense/moe, non-SWA; implies "
                         "--queue).  Default: full prefill at admission")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="exact shared-prefix cache: admissions that share "
                         "cached full-page prompt prefixes point at the "
                         "shared physical pages and prefill only the "
                         "suffix (dense/moe, non-SWA; implies --queue)")
    ap.add_argument("--prefix-cache-pages", type=int, default=None,
                    help="cap on cached prefix pages (LRU-evicted; "
                         "default: bounded by pool pressure only)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="with --queue: give every synthetic request the "
                         "same N-token system prompt (exercises the "
                         "prefix cache)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative decoding: a shallow self-draft "
                         "(the first --draft-layers layers of the SAME "
                         "packed weights) proposes tokens, one verify "
                         "pass accepts the longest greedy-agreeing "
                         "prefix + 1 — bit-exact vs sequential decode "
                         "(greedy, dense/moe, non-SWA; implies --queue)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="with --spec-decode: verify block size (the "
                         "draft proposes k-1 tokens; 1..k committed "
                         "per slot per tick)")
    ap.add_argument("--draft-layers", type=int, default=None,
                    help="with --spec-decode: self-draft depth in layers "
                         "(default: n_layers // 2)")
    ap.add_argument("--mesh", default=None,
                    help="serving mesh spec, e.g. 'tp=2' or 'dp=2,tp=4': "
                         "packed weights and KV page pools are sharded "
                         "under an explicit device mesh (tp -> 'model' "
                         "shards heads/hidden/vocab, dp/fsdp -> 'data'); "
                         "default is the degenerate 1-device mesh — the "
                         "SAME code path, not a fork.  On CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N first")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for weight init (ignored with "
                         "--ckpt-dir when a checkpoint is restored)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a Chrome-trace-event JSON of the serve "
                         "run (simulated tick clock: request spans, page/"
                         "prefix-cache counters, jit-compile instants) "
                         "and write it to PATH — open in Perfetto "
                         "(ui.perfetto.dev).  Host-side only: tokens are "
                         "bit-identical to an untraced run (implies "
                         "--queue)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        step, restored = ckpt.restore_latest(args.ckpt_dir, params)
        if restored is not None:
            params = restored
            print(f"restored step-{step} checkpoint")

    kv_fmt = args.kv_cache_format or ("bf16" if args.bf16 else "nvfp4")
    scfg = ServeConfig(batch_size=args.batch, max_len=args.max_len,
                       temperature=args.temperature,
                       kv_cache_format=kv_fmt,
                       page_size=args.page_size, max_slots=args.max_slots,
                       prefix_cache=args.prefix_cache,
                       prefix_cache_pages=args.prefix_cache_pages,
                       prefill_chunk=args.prefill_chunk,
                       spec_k=args.spec_k if args.spec_decode else None,
                       draft_layers=(args.draft_layers
                                     if args.spec_decode else None),
                       mesh=args.mesh)
    if args.mesh:
        from repro.distributed import sharding as shd
        from repro.distributed.specs import (packed_gather_ratio,
                                             packed_wire_bits_per_param)
        mesh = shd.make_serve_mesh(args.mesh)   # fail fast on device count
        print(f"serving mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}"
              f" over {mesh.devices.size} of {jax.device_count()} devices; "
              f"packed weight collectives move "
              f"{packed_wire_bits_per_param():.2f} bits/param "
              f"({packed_gather_ratio():.2f}x less than bf16)")
    qcfg = fqt.bf16_config() if args.bf16 else None
    rng = np.random.default_rng(0)

    if (args.prefix_cache or args.prefill_chunk or args.spec_decode
            or args.trace) and not args.queue:
        args.queue = 8          # continuous-engine knobs imply --queue

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(clock="tick", process="serve")

    if args.queue:
        # continuous batching: staggered arrivals through the scheduler
        eng = ContinuousEngine(cfg, params, scfg, qcfg=qcfg, tracer=tracer)
        shared = rng.integers(0, cfg.vocab_size, args.shared_prefix)
        reqs = [Request(rid=i,
                        prompt=np.concatenate(
                            [shared, rng.integers(0, cfg.vocab_size,
                                                  args.prompt_len)]),
                        max_new=args.max_new, arrival=i // 2)
                for i in range(args.queue)]
        t0 = time.perf_counter()
        res = eng.run(reqs)
        dt = time.perf_counter() - t0
        ntok = sum(len(o) for o in res.values())
        st = eng.scheduler.stats
        print(f"{ntok} tokens / {st['completed']} requests in {dt:.2f}s "
              f"({ntok / dt:.1f} tok/s incl. compile; slot util "
              f"{eng.scheduler.slot_utilization:.2f}; compiles: "
              f"prefill {eng.prefill_compiles}+"
              f"{eng.prefill_suffix_compiles}, decode "
              f"{eng.decode_compiles}, verify {eng.verify_compiles})")
        print(f"paging: {st['private_pages']} private + "
              f"{st['shared_pages']} shared + {st['demand_pages']} on-"
              f"demand pages; {st['preemptions']} preemptions")
        ms = eng.metrics.summary()
        print(f"latency (simulated ticks): TTFT p50 "
              f"{ms['ttft_ticks']['p50']:.0f} / p95 "
              f"{ms['ttft_ticks']['p95']:.0f}, TPOT p50 "
              f"{ms['tpot_ticks']['p50']:.2f}, goodput "
              f"{ms['goodput']:.2f}"
              + (f"; {len(eng.scheduler.prefill_log)} prefill chunks "
                 f"(<= {args.prefill_chunk} tok/slot/tick)"
                 if args.prefill_chunk else ""))
        if eng.scheduler.prefix_cache is not None:
            print(f"prefix cache: hit rate "
                  f"{eng.scheduler.prefix_hit_rate:.2f}, "
                  f"{st['prefix_tokens_skipped']} prefill tokens skipped, "
                  f"{st['prefilled_tokens']} prefilled")
        if args.spec_decode and "spec_accepted_per_tick_slot" in ms:
            acc, rate = (ms["spec_accepted_per_tick_slot"],
                         ms["spec_acceptance_rate"])
            print(f"speculative (k={args.spec_k}, draft "
                  f"{eng.draft_layers}/{cfg.n_layers} layers): "
                  f"{acc['mean']:.2f} accepted tokens/tick/slot "
                  f"(p50 {acc['p50']:.0f}, p95 {acc['p95']:.0f}), "
                  f"acceptance rate {rate['mean']:.2f} over "
                  f"{acc['n']} verify samples")
        if tracer is not None:
            tracer.export(args.trace)
            print(f"trace: {tracer.n_events} events "
                  f"({tracer.spans_opened} spans, "
                  f"{len(tracer.open_spans())} unclosed) -> {args.trace} "
                  f"(open in Perfetto: ui.perfetto.dev)")
        for rid in sorted(res)[:4]:
            print(f"req {rid}: {res[rid][:16].tolist()} ...")
        return

    eng = Engine(cfg, params, scfg, qcfg=qcfg)
    prompts = [rng.integers(0, cfg.vocab_size, args.prompt_len)
               for _ in range(args.batch)]
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, cfg.enc_seq, cfg.d_model)),
            jax.numpy.bfloat16)
    if cfg.family == "vlm":
        extras["prefix_embeds"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, cfg.vision_tokens, cfg.d_model)),
            jax.numpy.bfloat16)

    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new, extras=extras)
    dt = time.perf_counter() - t0
    ntok = sum(len(o) for o in out)
    print(f"{ntok} tokens in {dt:.2f}s  ({ntok / dt:.1f} tok/s, "
          f"incl. compile)")
    for i, o in enumerate(out[:4]):
        print(f"seq {i}: {o[:16].tolist()} ...")


if __name__ == "__main__":
    main()
