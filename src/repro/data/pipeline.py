"""Deterministic, restart-safe synthetic token pipeline.

Every batch is a pure function of (dataset_seed, step), so a job restarted
from a step-N checkpoint consumes exactly the tokens it would have seen — the
fault-tolerance contract the trainer relies on (no data-loader state to
checkpoint).  Hosts slice their shard by (host_id, num_hosts); the same
mechanism shards across the `data`/`pod` mesh axes at scale.

The generator is a Zipf-ish Markov stream rather than iid-uniform so that
language-model losses have structure to learn (quantization ablations need a
descending loss curve, not a flat one).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    # synthetic stream structure
    zipf_a: float = 1.2
    markov_mix: float = 0.7     # prob of following the Markov chain


class SyntheticLM:
    """Markov-chain token stream with Zipf marginals (numpy, host-side)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.marginal = ranks ** (-cfg.zipf_a)
        self.marginal /= self.marginal.sum()
        # sparse deterministic successor table: each token has 4 successors
        self.succ = rng.integers(0, V, size=(V, 4))

    def batch(self, step: int, *, host_id: int = 0,
              num_hosts: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if cfg.global_batch % num_hosts:
            raise ValueError("global_batch must divide across hosts")
        local = cfg.global_batch // num_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 64 + host_id)
        B, S = local, cfg.seq_len + 1           # +1 for the shifted target
        toks = np.empty((B, S), np.int64)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=B, p=self.marginal)
        follow = rng.random((B, S)) < cfg.markov_mix
        chain_pick = rng.integers(0, 4, size=(B, S))
        fresh = rng.choice(cfg.vocab_size, size=(B, S), p=self.marginal)
        for t in range(1, S):
            chained = self.succ[toks[:, t - 1], chain_pick[:, t]]
            toks[:, t] = np.where(follow[:, t], chained, fresh[:, t])
        return {"tokens": toks.astype(np.int32)}

    def iter_batches(self, start_step: int = 0, *, host_id: int = 0,
                     num_hosts: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, host_id=host_id, num_hosts=num_hosts)
            step += 1


def make_eval_batches(cfg: DataConfig, n: int = 8):
    """Held-out batches: negative step ids never seen in training."""
    ds = SyntheticLM(cfg)
    return [ds.batch(-(i + 1)) for i in range(n)]
