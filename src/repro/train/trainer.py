"""Trainer: the fault-tolerant training loop.

Production duties, scaled down to run on one host but structured for 1000+
nodes (DESIGN.md §6):

  * **checkpoint/restart** — atomic sharded checkpoints every
    ``ckpt_every`` steps; on start, ``Trainer.run`` resumes from the latest
    checkpoint (different mesh OK — elastic resharding in checkpoint/ckpt).
    The data pipeline is step-indexed, so the resumed run consumes exactly
    the token stream it would have seen.
  * **straggler mitigation** — per-step wall-time is tracked against a
    running median; a step slower than ``straggler_factor``× median is
    recorded (on a real cluster the event triggers hot-spare swap /
    re-slicing; the detection + accounting layer is what lives here).
  * **QAF auto-switch** (the paper's §4→§5 pipeline) — when the
    gradient-to-noise EMA crosses √3 (or at a fixed step), the trainer
    re-builds the step function with the QAF QuantConfig (FP4 forward, BF16
    backward) and re-warms the LR, continuing from the same state.
  * **preemption safety** — SIGTERM sets a flag; the loop checkpoints and
    exits cleanly at the next step boundary.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core import fqt, qaf
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.config import ModelConfig
from repro.optim import schedule
from repro.train import step as step_mod


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    seed: int = 0                     # root key when run() gets no key
    qaf: qaf.QAFConfig = dataclasses.field(default_factory=qaf.QAFConfig)
    # emit the quantize-once packed NVFP4 serving artifact at the end of
    # the run (<ckpt_dir>/serve_packed) — deploys restore 4-bit weights
    # directly into the Engine and never touch the bf16 training params
    export_packed: bool = True


class Trainer:
    def __init__(self, cfg: ModelConfig, qcfg: fqt.QuantConfig,
                 tcfg: step_mod.TrainConfig, run_cfg: TrainerConfig,
                 data_cfg: DataConfig, mesh=None):
        self.cfg, self.qcfg, self.tcfg = cfg, qcfg, tcfg
        self.run_cfg, self.data_cfg = run_cfg, data_cfg
        self.mesh = mesh
        self.data = SyntheticLM(data_cfg)
        self.history: List[Dict[str, float]] = []
        self.events: List[Dict[str, Any]] = []
        self.in_qaf = False
        self._stop = False
        self._step_fn = None

    # ---- lifecycle -------------------------------------------------------

    def _install_sigterm(self):
        try:
            signal.signal(signal.SIGTERM, lambda *_: setattr(
                self, "_stop", True))
        except ValueError:
            pass  # not on the main thread (tests)

    def _build_step(self, start_step: int = 0):
        qcfg = qaf.qaf_quant_config(self.qcfg) if self.in_qaf else self.qcfg
        tcfg = self.tcfg
        if self.in_qaf:
            tcfg = dataclasses.replace(
                tcfg, sched=qaf.qaf_lr_schedule(self.tcfg.sched,
                                                self.run_cfg.qaf,
                                                start_step))
        self._step_fn = step_mod.make_train_step(self.cfg, qcfg, tcfg,
                                                 self.mesh)
        if self.mesh is not None:
            self._step_fn = jax.jit(self._step_fn, donate_argnums=(0,))

    def init_or_restore(self, key) -> step_mod.TrainState:
        state = step_mod.init_state(self.cfg, self.tcfg, key)
        if self.run_cfg.ckpt_dir:
            step, restored = ckpt.restore_latest(self.run_cfg.ckpt_dir, state)
            if restored is not None:
                self.events.append({"kind": "restore", "step": int(step)})
                return restored
        return state

    # ---- the loop --------------------------------------------------------

    def run(self, key=None) -> step_mod.TrainState:
        key = key if key is not None else jax.random.PRNGKey(
            self.run_cfg.seed)
        self._install_sigterm()
        state = self.init_or_restore(key)
        self._build_step()
        start_step = int(state.step)
        durations: List[float] = []

        for step in range(start_step, self.run_cfg.total_steps):
            if self._stop:
                self.events.append({"kind": "preempt", "step": step})
                break
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch(step).items()}

            t0 = time.perf_counter()
            state, metrics = self._step_fn(state, batch)
            metrics = {k: float(v) for k, v in
                       jax.device_get(metrics).items()}
            dt = time.perf_counter() - t0

            # straggler accounting (skip compile steps: first of each phase)
            if len(durations) >= 5:
                med = float(np.median(durations[-50:]))
                if dt > self.run_cfg.straggler_factor * med:
                    self.events.append({"kind": "straggler", "step": step,
                                        "dt": dt, "median": med})
            durations.append(dt)

            metrics["step"] = step
            metrics["dt"] = dt
            self.history.append(metrics)

            # QAF switch (paper §5): threshold crossing or fixed step
            if not self.in_qaf and qaf.should_switch(
                    step, metrics["thr_crossed"] > 0.5, self.run_cfg.qaf):
                self.in_qaf = True
                self.events.append({"kind": "qaf_switch", "step": step,
                                    "gnr": metrics["gnr"]})
                self._build_step(start_step=step + 1)

            if (self.run_cfg.ckpt_dir
                    and (step + 1) % self.run_cfg.ckpt_every == 0):
                ckpt.save(self.run_cfg.ckpt_dir, step + 1, state,
                          keep=self.run_cfg.keep_ckpts)

        if self.run_cfg.ckpt_dir and (self._stop or True):
            ckpt.save(self.run_cfg.ckpt_dir, int(state.step), state,
                      keep=self.run_cfg.keep_ckpts)
            if self.run_cfg.export_packed:
                self.export_serving_artifact(state)
        return state

    def export_serving_artifact(self, state) -> Optional[str]:
        """Quantize-once export: pack every GEMM weight with THIS run's
        forward weight spec (its QAF/serving numerics) and checkpoint the
        packed tree under ``<ckpt_dir>/serve_packed`` — 4-bit on disk,
        restored directly into ``serve.Engine(..., pack_weights=False)``
        so deploys never touch the bf16 training weights.  Runs with no
        quantized forward (the bf16 baseline) export nothing: there is no
        packed-serving story for them."""
        if not self.run_cfg.ckpt_dir:
            return None
        spec = qaf.qaf_quant_config(self.qcfg).fwd_w
        if spec is None:
            return None
        from repro.serve.packing import pack_model_params
        packed = pack_model_params(self.cfg, state.params, spec)
        path = ckpt.save(os.path.join(self.run_cfg.ckpt_dir,
                                      "serve_packed"),
                         int(state.step), packed,
                         keep=self.run_cfg.keep_ckpts)
        self.events.append({"kind": "export_packed",
                            "step": int(state.step)})
        return path

    # ---- reporting -------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        h = self.history
        return {
            "steps": len(h),
            "final_loss": h[-1]["loss"] if h else None,
            "final_gnr": h[-1]["gnr"] if h else None,
            "qaf": self.in_qaf,
            "events": self.events,
        }
