"""Trainer: the fault-tolerant training loop.

Production duties, scaled down to run on one host but structured for 1000+
nodes (DESIGN.md §6):

  * **checkpoint/restart** — atomic sharded checkpoints every
    ``ckpt_every`` steps; on start, ``Trainer.run`` resumes from the latest
    checkpoint (different mesh OK — elastic resharding in checkpoint/ckpt).
    The data pipeline is step-indexed, so the resumed run consumes exactly
    the token stream it would have seen.
  * **straggler mitigation** — per-step wall-time is tracked against a
    running median; a step slower than ``straggler_factor``× median is
    recorded (on a real cluster the event triggers hot-spare swap /
    re-slicing; the detection + accounting layer is what lives here).
  * **QAF auto-switch** (the paper's §4→§5 pipeline) — when the
    gradient-to-noise EMA crosses √3 (or at a fixed step), the trainer
    re-builds the step function with the QAF QuantConfig (FP4 forward, BF16
    backward) and re-warms the LR, continuing from the same state.
  * **preemption safety** — SIGTERM sets a flag; the loop checkpoints and
    exits cleanly at the next step boundary.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core import fqt, qaf, quantize, threshold
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.config import ModelConfig
from repro.obs.trace import NULL_TRACER
from repro.optim import schedule
from repro.train import step as step_mod


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    seed: int = 0                     # root key when run() gets no key
    qaf: qaf.QAFConfig = dataclasses.field(default_factory=qaf.QAFConfig)
    # emit the quantize-once packed NVFP4 serving artifact at the end of
    # the run (<ckpt_dir>/serve_packed) — deploys restore 4-bit weights
    # directly into the Engine and never touch the bf16 training params
    export_packed: bool = True


class Trainer:
    def __init__(self, cfg: ModelConfig, qcfg: fqt.QuantConfig,
                 tcfg: step_mod.TrainConfig, run_cfg: TrainerConfig,
                 data_cfg: DataConfig, mesh=None, tracer=None):
        # quant-health telemetry (obs/trace.py, clock = optimizer step):
        # per-layer √3-floor ratios, E4M3 scale saturation/underflow,
        # rounding-mode tallies — emitted every ``log_every`` steps.  A
        # live tracer turns on the step's per-leaf gradient norms.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if self.tracer.enabled and not tcfg.layer_stats:
            tcfg = dataclasses.replace(tcfg, layer_stats=True)
        self.cfg, self.qcfg, self.tcfg = cfg, qcfg, tcfg
        self.run_cfg, self.data_cfg = run_cfg, data_cfg
        self.mesh = mesh
        self.data = SyntheticLM(data_cfg)
        self.history: List[Dict[str, float]] = []
        self.events: List[Dict[str, Any]] = []
        self.in_qaf = False
        self._stop = False
        self._step_fn = None
        self._leaf_info = None          # [(path, size)] in grad-leaf order

    # ---- lifecycle -------------------------------------------------------

    def _install_sigterm(self):
        try:
            signal.signal(signal.SIGTERM, lambda *_: setattr(
                self, "_stop", True))
        except ValueError:
            pass  # not on the main thread (tests)

    def _build_step(self, start_step: int = 0):
        qcfg = qaf.qaf_quant_config(self.qcfg) if self.in_qaf else self.qcfg
        tcfg = self.tcfg
        if self.in_qaf:
            tcfg = dataclasses.replace(
                tcfg, sched=qaf.qaf_lr_schedule(self.tcfg.sched,
                                                self.run_cfg.qaf,
                                                start_step))
        self._step_fn = step_mod.make_train_step(self.cfg, qcfg, tcfg,
                                                 self.mesh)
        if self.mesh is not None:
            self._step_fn = jax.jit(self._step_fn, donate_argnums=(0,))

    def init_or_restore(self, key) -> step_mod.TrainState:
        state = step_mod.init_state(self.cfg, self.tcfg, key)
        if self.run_cfg.ckpt_dir:
            step, restored = ckpt.restore_latest(self.run_cfg.ckpt_dir, state)
            if restored is not None:
                self.events.append({"kind": "restore", "step": int(step)})
                return restored
        return state

    # ---- the loop --------------------------------------------------------

    def run(self, key=None) -> step_mod.TrainState:
        key = key if key is not None else jax.random.PRNGKey(
            self.run_cfg.seed)
        self._install_sigterm()
        state = self.init_or_restore(key)
        self._build_step()
        start_step = int(state.step)
        durations: List[float] = []

        for step in range(start_step, self.run_cfg.total_steps):
            if self._stop:
                self.events.append({"kind": "preempt", "step": step})
                break
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch(step).items()}

            t0 = time.perf_counter()
            state, metrics = self._step_fn(state, batch)
            host = jax.device_get(metrics)
            layer_gnorms = host.pop("layer_gnorms", None)
            metrics = {k: float(v) for k, v in host.items()}
            dt = time.perf_counter() - t0

            if (self.tracer.enabled
                    and step % self.run_cfg.log_every == 0):
                self._emit_telemetry(step, metrics, layer_gnorms, state)

            # straggler accounting (skip compile steps: first of each phase)
            if len(durations) >= 5:
                med = float(np.median(durations[-50:]))
                if dt > self.run_cfg.straggler_factor * med:
                    self.events.append({"kind": "straggler", "step": step,
                                        "dt": dt, "median": med})
            durations.append(dt)

            metrics["step"] = step
            metrics["dt"] = dt
            self.history.append(metrics)

            # QAF switch (paper §5): threshold crossing or fixed step
            if not self.in_qaf and qaf.should_switch(
                    step, metrics["thr_crossed"] > 0.5, self.run_cfg.qaf):
                self.in_qaf = True
                self.events.append({"kind": "qaf_switch", "step": step,
                                    "gnr": metrics["gnr"]})
                self._build_step(start_step=step + 1)

            if (self.run_cfg.ckpt_dir
                    and (step + 1) % self.run_cfg.ckpt_every == 0):
                ckpt.save(self.run_cfg.ckpt_dir, step + 1, state,
                          keep=self.run_cfg.keep_ckpts)

        if self.run_cfg.ckpt_dir and (self._stop or True):
            ckpt.save(self.run_cfg.ckpt_dir, int(state.step), state,
                      keep=self.run_cfg.keep_ckpts)
            if self.run_cfg.export_packed:
                self.export_serving_artifact(state)
        return state

    # ---- quant-health telemetry ------------------------------------------

    def _emit_telemetry(self, step: int, metrics: Dict[str, float],
                        layer_gnorms, state) -> None:
        """One trace entry per logged step (clock = optimizer step): the
        paper's §4 health signals, per layer.

          * ``gnr``/``sigma_q`` gauges — the global ‖g‖/(σ_q·√d) EMA and
            the SR-residual noise estimate the step computed;
          * per-layer ``ratio`` gauges + the ``layers_below_sqrt3``
            counter — layers whose OWN gradient signal is under the √3
            floor (the global EMA averages these out; they are the early
            warning the paper's switch rule reacts to);
          * E4M3 block-scale saturation/underflow counters from a probe
            weight quantized with the active forward spec;
          * rounding-mode tallies — how many of the six quantization
            points ran SR vs RtN this step (flips when QAF switches).
        """
        trc = self.tracer
        trc.set_time(step)
        trc.gauge("loss", metrics["loss"])
        trc.gauge("grad_norm", metrics["grad_norm"])
        trc.gauge("sigma_q", metrics["sigma_q"])
        trc.gauge("gnr", metrics["gnr"])
        if metrics["thr_crossed"] > 0.5:
            trc.counter("sqrt3_crossed_steps")

        qcfg = qaf.qaf_quant_config(self.qcfg) if self.in_qaf else self.qcfg
        specs = [getattr(qcfg, p) for p in fqt.POINTS]
        trc.counter("rounding_sr_points",
                    sum(1 for s in specs if s is not None and s.stochastic))
        trc.counter("rounding_rtn_points",
                    sum(1 for s in specs
                        if s is not None and not s.stochastic))

        if layer_gnorms is not None:
            if self._leaf_info is None:
                leaves = jax.tree_util.tree_flatten_with_path(
                    state.params)[0]
                self._leaf_info = [(jax.tree_util.keystr(p), x.size)
                                   for p, x in leaves]
            below = 0
            for (name, size), g in zip(self._leaf_info,
                                       np.asarray(layer_gnorms)):
                r = threshold.layer_ratio(float(g), metrics["sigma_q"],
                                          size)
                trc.gauge(f"ratio{name}", r, track="layers")
                below += r < threshold.SQRT3
            if below:
                trc.counter("layers_below_sqrt3", below)

        spec = qcfg.fwd_w
        if spec is not None:
            probe = None
            for leaf in jax.tree.leaves(state.params):
                if leaf.ndim >= 2 and leaf.shape[-1] % spec.block == 0:
                    if probe is None or leaf.size > probe.size:
                        probe = leaf
            if probe is not None:
                h = quantize.scale_health(probe, spec)
                trc.counter("scale_blocks", h["blocks"])
                trc.counter("scale_saturated", h["saturated"])
                trc.counter("scale_underflow", h["underflow"])

    def export_serving_artifact(self, state) -> Optional[str]:
        """Quantize-once export: pack every GEMM weight with THIS run's
        forward weight spec (its QAF/serving numerics) and checkpoint the
        packed tree under ``<ckpt_dir>/serve_packed`` — 4-bit on disk,
        restored directly into ``serve.Engine(..., pack_weights=False)``
        so deploys never touch the bf16 training weights.  Runs with no
        quantized forward (the bf16 baseline) export nothing: there is no
        packed-serving story for them."""
        if not self.run_cfg.ckpt_dir:
            return None
        spec = qaf.qaf_quant_config(self.qcfg).fwd_w
        if spec is None:
            return None
        from repro.serve.packing import pack_model_params
        packed = pack_model_params(self.cfg, state.params, spec)
        path = ckpt.save(os.path.join(self.run_cfg.ckpt_dir,
                                      "serve_packed"),
                         int(state.step), packed,
                         keep=self.run_cfg.keep_ckpts)
        self.events.append({"kind": "export_packed",
                            "step": int(state.step)})
        return path

    # ---- reporting -------------------------------------------------------

    def summary(self) -> Dict[str, Any]:
        h = self.history
        return {
            "steps": len(h),
            "final_loss": h[-1]["loss"] if h else None,
            "final_gnr": h[-1]["gnr"] if h else None,
            "qaf": self.in_qaf,
            "events": self.events,
        }
