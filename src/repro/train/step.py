"""The FQT train step: loss, grads, optimizer, and the paper's §4 monitor.

One pjit-compiled function per (model cfg, quant cfg, mesh):

  1. loss/grads through ``registry.loss_fn`` — every matmul routes through
     ``fp4_matmul`` whose custom_vjp implements the paper's six quantization
     points (SR seeds derived from the step counter: deterministic,
     replayable after restart).
  2. gradient-to-noise monitor: σ_q is estimated from the actual SR
     quantization residual of the gradient tensors (paper Fig. 5 monitors
     ‖∇L‖/(σ_q·√d) against √3), EMA-tracked in ``ThresholdState``.
  3. optional inter-pod gradient compression (distributed/compression.py).
  4. AdamW with FP32 master weights + warmup/cosine LR.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import fqt, threshold
from repro.core.quantize import NVFP4
from repro.distributed import compression as comp
from repro.distributed import sharding as shd
from repro.models import registry
from repro.models.config import ModelConfig
from repro.optim import adamw, schedule


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: adamw.AdamWState
    thr: threshold.ThresholdState


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    sched: schedule.ScheduleConfig = schedule.ScheduleConfig()
    thr: threshold.ThresholdConfig = threshold.ThresholdConfig()
    compression: Optional[comp.CompressionConfig] = None
    remat: bool = True
    probe_sigma: bool = True     # estimate σ_q each step (cheap, elementwise)
    sigma_spec: Any = None       # spec for the σ_q probe (default NVFP4-SR)
    layer_stats: bool = False    # add per-leaf ‖g‖ to metrics (telemetry:
                                 # the trainer's per-layer √3-floor series)


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> TrainState:
    params = registry.init_params(cfg, key)
    return TrainState(jnp.zeros((), jnp.int32), params,
                      adamw.init(params, tcfg.opt), threshold.init())


def n_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def _estimate_sigma_q(grads, step, spec=None) -> jax.Array:
    """σ_q from the SR residual of quantizing the gradients themselves with
    the paper's NVFP4-SR spec (the same noise the update GEMM injects)."""
    spec = spec if spec is not None else NVFP4.with_rounding(stochastic=True)
    num = jnp.zeros(())
    den = jnp.zeros(())
    for i, g in enumerate(jax.tree.leaves(grads)):
        if g.ndim < 2 or g.shape[-1] % spec.block:
            continue
        key = jax.random.fold_in(
            jax.random.PRNGKey(jnp.asarray(step, jnp.uint32)), i)
        from repro.core.quantize import fake_quant
        q = fake_quant(g.astype(jnp.float32), spec, axis=-1, key=key)
        r = (q - g.astype(jnp.float32)).ravel()
        num += jnp.sum(r * r)
        den += float(r.size)     # python float: leaf sizes exceed int32
    return jnp.sqrt(num / jnp.maximum(den, 1.0) + 1e-30)


def make_train_step(cfg: ModelConfig, qcfg: fqt.QuantConfig,
                    tcfg: TrainConfig, mesh: Optional[Mesh] = None):
    """Returns train_step(state, batch) -> (state, metrics); pure, jittable.

    When ``mesh`` is given the returned fn is jitted with full GSPMD
    shardings (params FSDP×TP, batch DP) and donated state.
    """
    d = None  # filled lazily from the state

    def train_step(state: TrainState, batch):
        step = state.step
        seed = jnp.asarray(step, jnp.uint32) * jnp.uint32(0x9E3779B1) + 1

        def loss_fn(p):
            return registry.loss_fn(p, cfg, qcfg, batch, seed=seed,
                                    remat=tcfg.remat)

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params)

        if tcfg.compression is not None and mesh is not None \
                and "pod" in mesh.axis_names:
            ckey = jax.random.PRNGKey(jnp.asarray(step, jnp.uint32))
            grads = comp.pod_mean_grads(grads, ckey, mesh, tcfg.compression)

        # §4 monitor: ‖∇L‖ / (σ_q √d) vs √3
        gnorm = adamw.global_norm(grads)
        if tcfg.probe_sigma:
            sigma_q = _estimate_sigma_q(grads, step, tcfg.sigma_spec)
        else:
            sigma_q = state.thr.sigma_q
        dd = sum(x.size for x in jax.tree.leaves(grads))
        thr_state = threshold.update(state.thr, gnorm, dd, sigma_q, tcfg.thr)

        lr = schedule.lr_at(step, tcfg.sched)
        params, opt, opt_metrics = adamw.apply(grads, state.opt, tcfg.opt, lr)

        metrics = {
            "loss": loss.astype(jnp.float32),
            "nll": aux["nll"].astype(jnp.float32),
            "grad_norm": opt_metrics["grad_norm"],
            "lr": lr,
            "sigma_q": sigma_q,
            "gnr": thr_state.ratio_ema,          # gradient-to-noise ratio
            "thr_crossed": thr_state.crossed.astype(jnp.float32),
        }
        if tcfg.layer_stats:
            # per-leaf gradient norms, stacked in tree-leaf order — the
            # trainer pairs them with leaf paths/sizes on the host to
            # emit the per-layer ‖g_i‖/(σ_q·√d_i) trace series
            metrics["layer_gnorms"] = jnp.stack(
                [jnp.sqrt(jnp.sum(jnp.square(g.astype(jnp.float32))))
                 for g in jax.tree.leaves(grads)])
        return TrainState(step + 1, params, opt, thr_state), metrics

    if mesh is None:
        return jax.jit(train_step, donate_argnums=(0,))
    return train_step  # caller jits with explicit shardings (launch/train.py)


def state_shardings(state: TrainState, mesh: Mesh):
    pshard = shd.params_shardings(state.params, mesh)
    rep = NamedSharding(mesh, P())
    return TrainState(
        step=rep,
        params=pshard,
        opt=adamw.AdamWState(step=rep, master=pshard, m=pshard, v=pshard),
        thr=jax.tree.map(lambda _: rep, state.thr),
    )


def jit_train_step(cfg: ModelConfig, qcfg: fqt.QuantConfig,
                   tcfg: TrainConfig, mesh: Mesh, state_struct: TrainState):
    """Fully-sharded jitted train step for a production mesh."""
    fn = make_train_step(cfg, qcfg, tcfg, mesh)
    st_sh = state_shardings(state_struct, mesh)
    batch_sh = {"tokens": NamedSharding(mesh, shd.batch_spec(mesh))}
    rep = NamedSharding(mesh, P())
    mkeys = {"loss": 0, "nll": 0, "grad_norm": 0, "lr": 0, "sigma_q": 0,
             "gnr": 0, "thr_crossed": 0}
    if tcfg.layer_stats:
        mkeys["layer_gnorms"] = 0
    return jax.jit(
        fn,
        in_shardings=(st_sh, batch_sh),
        out_shardings=(st_sh, jax.tree.map(lambda _: rep, mkeys)),
        donate_argnums=(0,),
    )
