from repro.train.step import (TrainConfig, TrainState, init_state,
                              jit_train_step, make_train_step)
from repro.train.trainer import Trainer, TrainerConfig
