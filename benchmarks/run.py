"""Benchmark harness driver.

  PYTHONPATH=src python -m benchmarks.run              # quick set
  PYTHONPATH=src python -m benchmarks.run --full       # every paper figure
  PYTHONPATH=src python -m benchmarks.run --bench fig3 # one artifact

Prints ``bench,name,metric`` CSV (one row group per paper table/figure) and
a kernel micro-timing section.  Roofline numbers come from the dry-run
(launch/dryrun.py) — see benchmarks/roofline_report.py for the table.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import paper_figs as pf


def kernel_microbench(reps: int = 5):
    """Wall-time of the jnp fake-quant FQT matmul vs plain bf16 matmul on
    this host (CPU — relative numbers only; TPU perf comes from §Roofline)."""
    import jax
    import jax.numpy as jnp
    from repro.core import fqt

    rows = []
    M = K = N = 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.bfloat16)

    def timeit(fn, *args):
        jax.tree.leaves(fn(*args))[0].block_until_ready()   # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
            jax.tree.leaves(out)[0].block_until_ready()
        return (time.perf_counter() - t0) / reps * 1e6

    mm = jax.jit(lambda a, b: a @ b)
    rows.append(("kernel_us", "bf16_matmul_1k", timeit(mm, x, w)))
    fq = jax.jit(lambda a, b: fqt.fp4_matmul(
        a, b, cfg=fqt.nvfp4_paper_config(), seed=jnp.uint32(1)))
    rows.append(("kernel_us", "fqt_fwd_matmul_1k", timeit(fq, x, w)))

    # quantize-once packed weight: activation-only quantization per GEMM
    from repro.core.quantize import NVFP4, pack_quantize
    pw = pack_quantize(w, NVFP4, axis=-2)
    pq = jax.jit(lambda a, pw: fqt.fp4_matmul(a, pw, cfg=fqt.qaf_config()))
    rows.append(("kernel_us", "packed_fwd_matmul_1k", timeit(pq, x, pw)))
    return rows


def serving_weight_store():
    """Decode-path weight bytes: bf16 store vs quantize-once packed NVFP4.

    The decode step is weight-bandwidth-bound; every generated token
    streams the full weight store from HBM, so stored bytes/param IS the
    bandwidth ratio of the serving hot loop."""
    import jax
    from repro.configs import get_config
    from repro.core import fqt
    from repro.models import registry
    from repro.serve.packing import pack_model_params, weight_store_bytes

    cfg = get_config("llama2-60m").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    packed = pack_model_params(cfg, params, fqt.qaf_config().fwd_w)
    bf16 = weight_store_bytes(params)
    pk = weight_store_bytes(packed)
    from repro.core.quantize import PackedQuantizedTensor
    import numpy as np
    pleaves = [l for l in jax.tree_util.tree_leaves(
        packed, is_leaf=lambda x: isinstance(x, PackedQuantizedTensor))
        if isinstance(l, PackedQuantizedTensor)]
    gemm_bytes = sum(l.nbytes() for l in pleaves)
    gemm_params = sum(int(np.prod(l.shape)) for l in pleaves)
    return [
        ("serve_weight_bytes", "bf16_store", float(bf16)),
        ("serve_weight_bytes", "packed_nvfp4_store", float(pk)),
        ("serve_weight_bytes", "decode_traffic_ratio", bf16 / pk),
        ("serve_weight_bytes", "packed_bytes_per_gemm_param",
         gemm_bytes / gemm_params),
    ]


def kv_cache_bench():
    """Decode-attention cache traffic: bf16 vs block-quantized KV cache.

    Long-context decode attention is bound by KV cache HBM reads (every
    token streams the whole cache), so stored bytes/token IS the bandwidth
    ratio of the attention term.  Reports bytes/token per format plus the
    greedy-token agreement vs the bf16 cache on the smoke config."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.core import fqt
    from repro.models import registry

    cfg = get_config("llama2-60m").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    qcfg = fqt.qaf_config()
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    max_len, steps = 96, 24

    def cache_bytes_per_token(fmt):
        carry = registry.make_decode_state(cfg, 1, max_len,
                                           kv_cache_format=fmt)
        total = sum(int(l.size * l.dtype.itemsize)
                    for l in jax.tree_util.tree_leaves(carry))
        return total / max_len

    # teacher-forced greedy agreement: both caches see the SAME token
    # stream (the bf16 run's), so one early argmax flip on a near-flat
    # random-init logit row cannot cascade — the per-step agreement is the
    # bounded-divergence measure of the cache approximation itself.
    def greedy_stream(fmt, forced=None):
        """Decode `steps` greedy picks; with ``forced`` the next input is
        the bf16 run's pick (teacher forcing), else the own pick."""
        carry = registry.make_decode_state(cfg, 2, max_len,
                                           kv_cache_format=fmt)
        last, carry = registry.prefill(params, cfg, qcfg, toks, carry)
        picks, lgs = [], []
        tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
        for t in range(steps):
            logits, carry = registry.decode_step(params, cfg, qcfg, tok,
                                                 carry)
            pick = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            picks.append(np.asarray(pick))
            lgs.append(np.asarray(logits[:, -1], np.float32))
            tok = (pick if forced is None else forced[t])[:, None]
        return np.stack(picks), np.stack(lgs)

    rows, bpt = [], {}
    for fmt in ("bf16", "nvfp4", "fp8"):
        bpt[fmt] = cache_bytes_per_token(fmt)
        rows.append(("kv_cache_bytes_per_token", fmt, bpt[fmt]))
    # bf16 pass records the forced token stream + reference logits
    ref_picks, ref_lgs = greedy_stream("bf16")
    forced = [jnp.asarray(p) for p in ref_picks]
    for fmt in ("nvfp4", "fp8"):
        picks, lgs = greedy_stream(fmt, forced)
        rows.append(("kv_cache_traffic_ratio", fmt,
                     bpt["bf16"] / bpt[fmt]))
        rows.append(("kv_cache_token_agreement_vs_bf16", fmt,
                     float(np.mean(picks == ref_picks))))
        # the bounded-divergence measure proper: relative logit error (the
        # token flips above happen on near-tied random-init logit rows)
        rows.append(("kv_cache_rel_logit_rmse", fmt,
                     float(np.sqrt(np.mean((lgs - ref_lgs) ** 2))
                           / np.sqrt(np.mean(ref_lgs ** 2)))))
    return rows


def serve_throughput_bench():
    """Continuous batching vs lockstep on a seeded synthetic arrival trace.

    Requests with mixed prompt lengths / budgets / arrival ticks stream
    through the ContinuousEngine's scheduler (paged NVFP4 KV cache, slot
    reuse).  Reports tokens/s (wall clock, informational only — nothing
    asserts on it), slot utilization, page-pool size and cache bytes per
    token; the trace itself is deterministic (tick-indexed arrivals, fixed
    PRNG seed — no wall-clock dependence anywhere in the numbers that
    matter)."""
    import time

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core.quantize import kv_bytes_per_elem
    from repro.models import registry
    from repro.serve import ContinuousEngine, Request, ServeConfig

    cfg = get_config("llama2-60m").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    slots, max_len = 4, 96
    scfg = ServeConfig(batch_size=slots, max_len=max_len, eos_id=-1,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=8)
    eng = ContinuousEngine(cfg, params, scfg)

    rng = np.random.default_rng(0)
    n_req = 10
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 16))),
                    max_new=int(rng.integers(6, 20)),
                    arrival=int(i // 3))
            for i in range(n_req)]
    eng.run(reqs)                                   # warm-up: compiles
    t0 = time.perf_counter()
    res = eng.run(reqs)
    dt = time.perf_counter() - t0
    ntok = sum(len(o) for o in res.values())
    sched = eng.scheduler
    kv_elems = 2 * cfg.n_kv_heads * cfg.hd * cfg.n_layers
    return [
        ("serve_throughput", "requests_completed",
         float(sched.stats["completed"])),
        ("serve_throughput", "tokens_generated", float(ntok)),
        ("serve_throughput", "tokens_per_s", ntok / dt),
        ("serve_throughput", "slot_utilization", sched.slot_utilization),
        ("serve_throughput", "decode_steps", float(sched.stats["decode_steps"])),
        ("serve_throughput", "page_pool_pages", float(sched.total_pages)),
        ("serve_throughput", "cache_bytes_per_token",
         kv_bytes_per_elem(scfg.kv_cache_format) * kv_elems),
        ("serve_throughput", "prefill_compiles", float(eng.prefill_compiles)),
        ("serve_throughput", "decode_compiles", float(eng.decode_compiles)),
    ]


def spec_decode_bench():
    """Speculative decoding acceptance trajectory: k in {2, 4} x draft
    depth {1, full}.  Every speculative stream is asserted BIT-identical
    to the non-speculative engine before its numbers are recorded — the
    trajectory measures pure throughput movement, never token drift.
    The accepted-tokens/tick/slot metric is the speedup story: mean > 1
    means the verify program advances more than one committed token per
    tick per slot (full-depth self-draft pins the ceiling at exactly k,
    acceptance rate 1.0)."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve import ContinuousEngine, Request, ServeConfig

    cfg = get_config("llama2-60m").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(8, 24))),
                    max_new=int(rng.integers(8, 16)),
                    arrival=int(i // 2))
            for i in range(6)]

    def scfg(**kw):
        return ServeConfig(batch_size=2, max_len=96, eos_id=-1,
                           kv_cache_format="nvfp4", page_size=16, **kw)

    def run(sc):
        eng = ContinuousEngine(cfg, params, sc)
        res = eng.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                               arrival=r.arrival) for r in reqs])
        return res, eng

    want, _ = run(scfg())
    rows = []
    for k in (2, 4):
        for dl in (1, cfg.n_layers):
            res, eng = run(scfg(spec_k=k, draft_layers=dl))
            for rid in want:
                np.testing.assert_array_equal(
                    res[rid], want[rid],
                    err_msg=f"spec k={k} dl={dl} drifted from sequential")
            s = eng.metrics.summary()
            tag = f"k{k}_draft{dl}"
            acc = s["spec_accepted_per_tick_slot"]
            rows += [
                ("serve_spec", f"{tag}_accepted_per_tick_slot_mean",
                 float(acc["mean"])),
                ("serve_spec", f"{tag}_accepted_per_tick_slot_p50",
                 float(acc["p50"])),
                ("serve_spec", f"{tag}_accepted_per_tick_slot_p95",
                 float(acc["p95"])),
                ("serve_spec", f"{tag}_acceptance_rate_mean",
                 float(s["spec_acceptance_rate"]["mean"])),
                ("serve_spec", f"{tag}_verify_ticks", float(acc["n"])),
                ("serve_spec", f"{tag}_verify_compiles",
                 float(eng.verify_compiles)),
            ]
    return rows


def prefix_cache_bench():
    """Exact shared-prefix cache: warm admissions skip the shared pages.

    A seeded arrival trace where requests share one of two long system
    prompts (the multi-user serving shape) streams through the continuous
    engine twice — prefix cache on vs off.  Reports the prefix hit rate,
    prefill tokens skipped vs prefilled, pages shared vs private vs
    allocated on demand, preemptions, and cache bytes/token.  Everything
    asserted-on elsewhere is tick/accounting-based — no wall clock (the
    interpret-mode caveat)."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core.quantize import kv_bytes_per_elem
    from repro.models import registry
    from repro.serve import ContinuousEngine, Request, ServeConfig

    cfg = get_config("llama2-60m").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    slots, max_len, psz = 4, 96, 16
    rng = np.random.default_rng(0)
    sys_prompts = [rng.integers(0, cfg.vocab_size, 40) for _ in range(2)]
    n_req = 10
    reqs = [Request(rid=i,
                    prompt=np.concatenate(
                        [sys_prompts[i % 2],
                         rng.integers(0, cfg.vocab_size,
                                      int(rng.integers(2, 8)))]),
                    max_new=int(rng.integers(6, 16)),
                    arrival=int(i // 2))
            for i in range(n_req)]

    def serve(prefix_cache):
        scfg = ServeConfig(batch_size=slots, max_len=max_len, eos_id=-1,
                           kv_cache_format="nvfp4", page_size=psz,
                           decode_chunk=8, prefix_cache=prefix_cache)
        eng = ContinuousEngine(cfg, params, scfg)
        eng.run(reqs)
        return eng.scheduler

    warm, cold = serve(True), serve(False)
    ws, cs = warm.stats, cold.stats
    kv_elems = 2 * cfg.n_kv_heads * cfg.hd * cfg.n_layers
    return [
        ("prefix_cache", "requests_completed", float(ws["completed"])),
        ("prefix_cache", "hit_rate", warm.prefix_hit_rate),
        ("prefix_cache", "prefill_tokens_skipped",
         float(ws["prefix_tokens_skipped"])),
        ("prefix_cache", "prefill_tokens_warm", float(ws["prefilled_tokens"])),
        ("prefix_cache", "prefill_tokens_cold", float(cs["prefilled_tokens"])),
        ("prefix_cache", "pages_shared", float(ws["shared_pages"])),
        ("prefix_cache", "pages_private", float(ws["private_pages"])),
        ("prefix_cache", "pages_on_demand", float(ws["demand_pages"])),
        ("prefix_cache", "preemptions", float(ws["preemptions"])),
        ("prefix_cache", "cache_bytes_per_token",
         kv_bytes_per_elem("nvfp4") * kv_elems),
        ("prefix_cache", "slot_utilization", warm.slot_utilization),
    ]


def serve_sharded_bench():
    """Mesh-native serving: packed-weight wire accounting + engine trace.

    Sharded serving moves weights in the SAME wire format it stores them:
    uint8 nibble codes + f8 block scales (~4.5 bits/param for NVFP4 block
    16) instead of 16-bit bf16 gathers — the accounting here is exact byte
    counts over the packed model, checked against the closed-form
    ``distributed/specs`` numbers.  The engine trace runs on the default
    1-device mesh, which is the SAME code path TP=N serving takes
    (benchmarks run without forced host device counts)."""
    import time

    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core import fqt
    from repro.core.quantize import PackedQuantizedTensor
    from repro.distributed.specs import (packed_gather_ratio,
                                         packed_wire_bits_per_param)
    from repro.models import registry
    from repro.serve import ContinuousEngine, Request, ServeConfig
    from repro.serve.packing import pack_model_params, weight_wire_bytes

    cfg = get_config("llama2-60m").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    packed = pack_model_params(cfg, params, fqt.qaf_config().fwd_w)
    pleaves = [l for l in jax.tree_util.tree_leaves(
        packed, is_leaf=lambda x: isinstance(x, PackedQuantizedTensor))
        if isinstance(l, PackedQuantizedTensor)]
    gemm_params = sum(int(np.prod(l.shape)) for l in pleaves)
    wire = sum(l.wire_nbytes() for l in pleaves)
    bf16_wire = 2 * gemm_params
    rows = [
        ("serve_sharded", "gemm_params", float(gemm_params)),
        ("serve_sharded", "wire_bytes_packed", float(wire)),
        ("serve_sharded", "wire_bytes_bf16", float(bf16_wire)),
        ("serve_sharded", "wire_bits_per_param", wire * 8 / gemm_params),
        ("serve_sharded", "wire_bits_per_param_model",
         packed_wire_bits_per_param()),
        ("serve_sharded", "gather_ratio_vs_bf16", bf16_wire / wire),
        ("serve_sharded", "gather_ratio_model", packed_gather_ratio()),
        ("serve_sharded", "tree_wire_bytes", float(weight_wire_bytes(packed))),
    ]

    scfg = ServeConfig(batch_size=4, max_len=96, eos_id=-1,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=8, mesh=None)
    eng = ContinuousEngine(cfg, params, scfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(rng.integers(4, 16))),
                    max_new=int(rng.integers(6, 16)),
                    arrival=int(i // 3))
            for i in range(8)]
    eng.run(reqs)                                   # warm-up: compiles
    t0 = time.perf_counter()
    res = eng.run(reqs)
    dt = time.perf_counter() - t0
    ntok = sum(len(o) for o in res.values())
    rows += [
        ("serve_sharded", "mesh_devices", float(eng.mesh.devices.size)),
        ("serve_sharded", "tokens_per_s", ntok / dt),
        ("serve_sharded", "prefill_compiles", float(eng.prefill_compiles)),
        ("serve_sharded", "decode_compiles", float(eng.decode_compiles)),
    ]
    return rows


def traffic_bench():
    """Multi-tenant traffic trajectory: TTFT/TPOT/goodput percentiles.

    A seeded three-tenant workload (serve/workload.py: Poisson + burst
    arrivals, per-tenant prompt mixes, shared system prompts, aborts and
    timeouts) streams through the continuous engine with chunked prefill
    + the prefix cache; serve/metrics.py records the lifecycle in
    SIMULATED TICKS.  Every number here is tick/accounting-based and
    deterministic — the recorded trajectory is comparable across PRs
    (no wall clock anywhere)."""
    import jax
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve import (ContinuousEngine, ServeConfig, TenantSpec,
                             WorkloadConfig, as_requests,
                             generate_workload)

    cfg = get_config("llama2-60m").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    wcfg = WorkloadConfig(tenants=(
        TenantSpec("chat", rate=0.45, prompt_lens=(6, 12, 20),
                   prompt_probs=(0.5, 0.3, 0.2), system_prompt_len=16,
                   max_new=10, deadline_slack=24),
        TenantSpec("batch", rate=0.15, prompt_lens=(40,), max_new=6,
                   timeout=12, burst_every=10, burst_size=2),
        # long prompts + a tight abort window: the aborts land MID-
        # chunked-prefill, so the recorded trajectory exercises the
        # cancellation path, not just happy completions
        TenantSpec("flaky", rate=0.2, prompt_lens=(60,), max_new=8,
                   abort_prob=0.6, abort_after=2),
    ), ticks=24, seed=11, vocab=cfg.vocab_size)
    reqs = as_requests(generate_workload(wcfg))
    scfg = ServeConfig(batch_size=4, max_len=96, eos_id=-1,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=8, prefix_cache=True,
                       prefill_chunk=16)
    eng = ContinuousEngine(cfg, params, scfg)
    eng.run(reqs)
    s = eng.metrics.summary()
    rows = [
        ("traffic", "requests_submitted", float(s["submitted"])),
        ("traffic", "requests_completed", float(s["completed"])),
        ("traffic", "requests_cancelled", float(s["cancelled"])),
        ("traffic", "goodput", float(s["goodput"])),
        ("traffic", "ticks", float(s["ticks"])),
    ]
    for met in ("ttft_ticks", "tpot_ticks"):
        for p in ("p50", "p95", "p99"):
            rows.append(("traffic", f"{met}_{p}", float(s[met][p])))
    rows += [
        ("traffic", "queue_depth_p95", float(s["queue_depth"]["p95"])),
        ("traffic", "queue_depth_max", float(s["queue_depth"]["max"])),
        ("traffic", "preemptions", float(s["counters"]["preemptions"])),
        ("traffic", "prefix_hit_rate", eng.scheduler.prefix_hit_rate),
        ("traffic", "prefill_chunks_issued",
         float(len(eng.scheduler.prefill_log))),
        ("traffic", "chunk_compiles", float(eng.chunk_compiles)),
        ("traffic", "suffix_compiles",
         float(eng.prefill_suffix_compiles)),
        ("traffic", "decode_compiles", float(eng.decode_compiles)),
    ]
    return rows


def lint_stats_bench():
    """fp4lint counters for the artifact: per-rule finding counts, files
    scanned, pragma suppressions and runtime.  Recording them per PR makes
    the suppressed-vs-fixed trajectory legible — a rising suppressed count
    with a flat finding count means violations are being pragma'd away
    instead of fixed.  Jax-free (repro.analysis is pure stdlib)."""
    import os

    from repro.analysis import RULES, lint_paths

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    findings, stats = lint_paths(root=root)
    rows = [
        ("lint", "files_scanned", float(stats.files_scanned)),
        ("lint", "findings_total", float(stats.findings)),
        ("lint", "suppressed", float(stats.suppressed)),
        ("lint", "parse_errors", float(stats.parse_errors)),
        ("lint", "runtime_ms", stats.runtime_s * 1e3),
    ]
    for rule in sorted(RULES):
        rows.append(("lint", f"findings_{rule.replace('-', '_')}",
                     float(stats.per_rule.get(rule, 0))))
    return rows


def obs_bench():
    """Observability cost + trace volume: the three-tenant traffic
    workload (same seeded config as ``traffic``) runs with a live tracer
    attached, recording how many events / spans / counter series the
    serve path emits and how many bytes the exported Chrome trace weighs.
    The two timing rows are host microbenchmarks: recording-tracer
    emission throughput (events/s) and the disabled ``NULL_TRACER``
    per-call cost in ns — the "zero when off" claim, measured."""
    import os
    import tempfile
    import time as _time

    import jax
    from repro.configs import get_config
    from repro.models import registry
    from repro.obs import NULL_TRACER, Tracer
    from repro.serve import (ContinuousEngine, ServeConfig, TenantSpec,
                             WorkloadConfig, as_requests,
                             generate_workload)

    cfg = get_config("llama2-60m").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    wcfg = WorkloadConfig(tenants=(
        TenantSpec("chat", rate=0.45, prompt_lens=(6, 12, 20),
                   prompt_probs=(0.5, 0.3, 0.2), system_prompt_len=16,
                   max_new=10, deadline_slack=24),
        TenantSpec("batch", rate=0.15, prompt_lens=(40,), max_new=6,
                   timeout=12, burst_every=10, burst_size=2),
        TenantSpec("flaky", rate=0.2, prompt_lens=(60,), max_new=8,
                   abort_prob=0.6, abort_after=2),
    ), ticks=24, seed=11, vocab=cfg.vocab_size)
    reqs = as_requests(generate_workload(wcfg))
    scfg = ServeConfig(batch_size=4, max_len=96, eos_id=-1,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=8, prefix_cache=True,
                       prefill_chunk=16)
    trc = Tracer(clock="tick", process="serve")
    eng = ContinuousEngine(cfg, params, scfg, tracer=trc)
    eng.run(reqs)

    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        trc.export(path)
        trace_bytes = os.path.getsize(path)
    finally:
        os.unlink(path)

    # recording-path emission throughput (pure host, no engine)
    mtrc = Tracer()
    n = 50_000
    t0 = _time.perf_counter()
    for _ in range(n):
        mtrc.counter("x")
    emit_per_s = n / (_time.perf_counter() - t0)

    # disabled path: the no-op singleton's per-call cost
    n = 200_000
    t0 = _time.perf_counter()
    for _ in range(n):
        NULL_TRACER.counter("x")
    noop_ns = (_time.perf_counter() - t0) / n * 1e9

    return [
        ("obs", "trace_events", float(trc.n_events)),
        ("obs", "spans_opened", float(trc.spans_opened)),
        ("obs", "spans_unclosed", float(len(trc.open_spans()))),
        ("obs", "counter_series", float(len(trc.counters))),
        ("obs", "trace_bytes", float(trace_bytes)),
        ("obs", "emit_events_per_s", emit_per_s),
        ("obs", "disabled_noop_ns_per_call", noop_ns),
    ]


BENCHES = {
    "fig1": pf.fig1_scale_formats,
    "fig2": pf.fig2_block_sizes,
    "fig3": pf.fig3_rounding_modes,
    "fig4": pf.fig4_quadratic,
    "fig5": pf.fig5_threshold_model,
    "fig6": pf.fig6_fqt_vs_bf16,
    "table2": pf.table2_settings,
    "kernels": kernel_microbench,
    "serve_weights": serving_weight_store,
    "kv_cache": kv_cache_bench,
    "serve_throughput": serve_throughput_bench,
    "spec_decode": spec_decode_bench,
    "prefix_cache": prefix_cache_bench,
    "serve_sharded": serve_sharded_bench,
    "traffic": traffic_bench,
    "lint": lint_stats_bench,
    "obs": obs_bench,
}

QUICK = ("table2", "fig4", "kernels", "fig5", "fig6", "serve_weights",
         "kv_cache", "serve_sharded", "traffic", "lint")

# the serving artifact (BENCH_serve.json): throughput, cache bytes/token,
# speculative acceptance trajectory, prefix-cache hit rate, sharded-
# weights wire accounting, the multi-tenant TTFT/TPOT/goodput
# trajectory, lint trajectory, observability cost/volume
SERVE_BENCHES = ("serve_weights", "kv_cache", "serve_throughput",
                 "spec_decode", "prefix_cache", "serve_sharded", "traffic",
                 "lint", "obs")


def _merge_bench_json(existing: dict, new_groups: dict) -> dict:
    """Merge freshly collected per-bench groups into an existing
    BENCH_serve.json payload: replaced at GROUP granularity, every other
    recorded group kept verbatim — a partial re-run (``--bench traffic
    --json``) can never clobber the rest of the recorded trajectory."""
    benches = dict(existing.get("benches", {}) or {})
    benches.update(new_groups)
    out = dict(existing)
    out["generated_by"] = "benchmarks.run --json"
    out["benches"] = benches
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="run every paper figure (hours on CPU)")
    ap.add_argument("--bench", default=None, choices=sorted(BENCHES))
    ap.add_argument("--json", nargs="?", const="BENCH_serve.json",
                    default=None, metavar="PATH",
                    help="also run the serving benches and write their rows "
                         "as JSON (default path: BENCH_serve.json)")
    args = ap.parse_args(argv)

    names = ([args.bench] if args.bench
             else sorted(BENCHES) if args.full
             else list(SERVE_BENCHES) if args.json else list(QUICK))
    if args.json and not args.bench:
        # an explicit --bench stays a PARTIAL run: only that bench's
        # group is (re)written, the merge below keeps the rest
        names += [n for n in SERVE_BENCHES if n not in names]
    collected = {}
    print("bench,name,value")
    for name in names:
        t0 = time.time()
        try:
            rows = BENCHES[name]()
        except Exception as e:                                # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}")
            continue
        for group, key, val in rows:
            print(f"{group},{key},{val:.6g}")
            collected.setdefault(group, {})[key] = float(f"{val:.6g}")
        print(f"# {name} took {time.time() - t0:.1f}s", file=sys.stderr)
    if args.json:
        import json
        import os
        serve_groups = {g: v for g, v in collected.items()
                        if g.startswith(("serve", "kv_cache", "prefix",
                                         "traffic", "lint", "obs"))}
        existing = {}
        if os.path.exists(args.json):
            try:
                with open(args.json) as f:
                    existing = json.load(f)
            except (ValueError, OSError):
                existing = {}        # unreadable artifact: rewrite fresh
        with open(args.json, "w") as f:
            json.dump(_merge_bench_json(existing, serve_groups), f,
                      indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json} ({len(serve_groups)} group(s) "
              f"updated)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
