"""Benchmarks reproducing the paper's tables/figures at laptop scale.

One function per paper artifact (each returns rows of
``name,metric,value``):

  fig1_scale_formats   — 350M-family Llama, FP4 E2M1 data, block 16, scale
                         formats E1M6..E8M0: final train loss per format.
  fig2_block_sizes     — block sizes {8,16,32,64,128} × scales {E8M0,E4M3}.
  fig3_rounding_modes  — SR applied at each of the six GEMM points alone.
  fig4_quadratic       — the §4 toy quadratic with σ_q = k·σ_crit.
  fig5_threshold_model — 60M-family model, mid-training precision switch,
                         gradient-to-noise ratio vs √3.
  fig6_fqt_vs_bf16     — the main experiment: NVFP4 FQT vs BF16 + QAF gap
                         closing (reduced: ~10M params, few hundred steps).
  table2_settings      — the quantization settings comparison (static).
  table3_downstream    — proxy: held-out perplexity BF16 vs FP4 vs FP4+QAF.

Scale note: the paper trains 350M/7B models for 10⁵ steps on 256
accelerators; these benches shrink width/steps so each runs in minutes on
CPU while preserving every qualitative claim (ordering of formats, SR/RtN
asymmetry, √3 transition, QAF gap-closing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import fqt, threshold
from repro.core.quantize import NVFP4, MXFP4, BlockQuantSpec
from repro.data.pipeline import DataConfig, SyntheticLM, make_eval_batches
from repro.models import registry
from repro.optim import adamw, schedule
from repro.train import TrainConfig, init_state, make_train_step


# ---- shared reduced-scale training loop ---------------------------------------


@dataclasses.dataclass(frozen=True)
class BenchScale:
    steps: int = 120
    batch: int = 8
    seq: int = 64
    lr: float = 1e-3
    seed: int = 0
    arch: str = "llama2-60m"
    sched_steps: int = 0     # >0: schedule horizon != executed steps


def train_loss_curve(qcfg: fqt.QuantConfig, scale: BenchScale,
                     eval_every: int = 0,
                     sigma_spec=None) -> Tuple[List[float], Dict]:
    """Train the reduced model with the given quant config; returns the loss
    curve (and the final state bundle for follow-up phases)."""
    cfg = get_config(scale.arch).smoke()
    tcfg = TrainConfig(
        opt=adamw.AdamWConfig(lr_peak=scale.lr),
        sched=schedule.ScheduleConfig(
            peak_lr=scale.lr, warmup_steps=20,
            total_steps=scale.sched_steps or scale.steps),
        remat=False, probe_sigma=True, sigma_spec=sigma_spec,
    )
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=scale.seq,
                                  global_batch=scale.batch,
                                  seed=1234 + scale.seed))
    state = init_state(cfg, tcfg, jax.random.PRNGKey(scale.seed))
    step_fn = make_train_step(cfg, qcfg, tcfg)
    losses, gnrs = [], []
    for step in range(scale.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        gnrs.append(float(m["gnr"]))
    return losses, {"state": state, "cfg": cfg, "tcfg": tcfg, "data": data,
                    "gnr": gnrs}


def _tail(losses: List[float], k: int = 10) -> float:
    return float(np.mean(losses[-k:]))


# ---- Fig. 1: scale-format sweep -------------------------------------------------


def fig1_scale_formats(scale: Optional[BenchScale] = None):
    scale = scale or BenchScale()
    rows = []
    for sf in ("e1m6", "e2m5", "e3m4", "e4m3", "e5m2", "e6m1", "e8m0"):
        spec = BlockQuantSpec(data_fmt="e2m1", scale_fmt=sf, block=16,
                              two_level=(sf != "e8m0"))
        qcfg = fqt.fqt_config(spec)
        losses, _ = train_loss_curve(qcfg, scale)
        rows.append(("fig1_scale_format", sf, _tail(losses)))
    return rows


# ---- Fig. 2: block-size sweep ----------------------------------------------------


def fig2_block_sizes(scale: Optional[BenchScale] = None):
    scale = scale or BenchScale()
    rows = []
    for sf in ("e8m0", "e4m3"):
        for block in (8, 16, 32, 64):
            spec = BlockQuantSpec(data_fmt="e2m1", scale_fmt=sf, block=block,
                                  two_level=(sf != "e8m0"))
            losses, _ = train_loss_curve(fqt.fqt_config(spec), scale)
            rows.append((f"fig2_block_{sf}", str(block), _tail(losses)))
    return rows


# ---- Fig. 3: rounding-mode sweep ---------------------------------------------------


def fig3_rounding_modes(scale: Optional[BenchScale] = None):
    scale = scale or BenchScale()
    rows = []
    base, _ = train_loss_curve(fqt.fqt_config(NVFP4, frozenset()), scale)
    rows.append(("fig3_sr_point", "none(all_rtn)", _tail(base)))
    for point in fqt.POINTS:
        qcfg = fqt.fqt_config(NVFP4, frozenset({point}))
        losses, _ = train_loss_curve(qcfg, scale)
        rows.append(("fig3_sr_point", point, _tail(losses)))
    paper, _ = train_loss_curve(fqt.nvfp4_paper_config(), scale)
    rows.append(("fig3_sr_point", "paper(bwd_g+upd_g+upd_a)", _tail(paper)))
    return rows


# ---- Fig. 4: quadratic toy model ----------------------------------------------------


def fig4_quadratic(d: int = 256, steps: int = 300):
    """GD on ½·θᵀHθ with FIXED gradient noise σ_q = k·σ_crit(θ₀) (§4.2).

    σ is pinned at k× the critical level of the INITIAL gradient: runs
    with k≥1 start at/below the √3 threshold and stall near their noise
    floor; k<1 tracks noiseless descent until ‖∇L‖ decays to √(3d)·σ.
    Reported: final loss (stall level) — the paper's Fig. 4 ordering.
    """
    rng = np.random.default_rng(0)
    lam = rng.uniform(0.5, 1.5, size=d)           # concentrated spectrum
    theta0 = rng.standard_normal(d)
    g0 = lam * theta0
    sigma_crit0 = float(np.linalg.norm(g0)) / np.sqrt(3 * d)
    rows = []
    for k in (2.0, 1.0, 0.5, 0.0):
        sigma = k * sigma_crit0
        theta = jnp.asarray(theta0)
        lamj = jnp.asarray(lam)
        key = jax.random.PRNGKey(1)
        losses = []
        for t in range(steps):
            g = lamj * theta
            gnorm = float(jnp.linalg.norm(g))
            key, sub = jax.random.split(key)
            gq = g + sigma * jax.random.normal(sub, (d,))
            # optimal step size under noise (paper Step 6)
            num = gnorm ** 2
            den = float(jnp.sum(lamj * g * g)) + sigma ** 2 * \
                float(jnp.sum(lamj))
            eta = num / max(den, 1e-30)
            theta = theta - eta * gq
            losses.append(float(0.5 * jnp.sum(lamj * theta * theta)))
        rows.append(("fig4_quadratic_k", str(k), losses[-1]))
    return rows


# ---- Fig. 5: √3 threshold on a real model --------------------------------------------


def fig5_threshold_model(scale: Optional[BenchScale] = None,
                         switch_at: Optional[int] = None):
    """Low-precision pretrain, then mid-training switch of the backward
    path to BF16 (the paper's Fig. 5 protocol); reports the loss gap to a
    BF16 baseline before/after the switch and the gradient-to-noise ratio.

    Scale note: at smoke scale NVFP4 noise is NOT binding (the ratio stays
    ≫√3 for the first few hundred steps), so — like the paper drives a 60M
    model into the binding regime with long training — we use a coarser
    format (E2M1 data, block-128 E8M0 scales, SR everywhere) whose noise
    puts the ratio near/below √3 from the start.  The claim validated is
    the paper's: when the ratio is below √3, raising backward precision
    closes the gap to the BF16 baseline.
    """
    from repro.core.quantize import BlockQuantSpec
    scale = scale or BenchScale(steps=160)
    switch_at = switch_at or scale.steps // 2

    base_losses, _ = train_loss_curve(fqt.bf16_config(), scale)

    noisy_spec = BlockQuantSpec(data_fmt="e2m1", scale_fmt="e8m0",
                                block=128, two_level=False,
                                stochastic=True)
    # NVFP4 forward; COARSE SR backward/update — isolates gradient noise
    # (the quantity the §4 theory bounds) exactly as the paper's protocol.
    from repro.core.quantize import NVFP4 as _NV
    noisy_cfg = fqt.QuantConfig(
        fwd_w=_NV, fwd_a=_NV,
        bwd_w=noisy_spec, bwd_g=noisy_spec,
        upd_g=noisy_spec, upd_a=noisy_spec)

    # phase 1 (schedule horizon = full run)
    losses1, bundle = train_loss_curve(
        noisy_cfg,
        dataclasses.replace(scale, steps=switch_at,
                            sched_steps=scale.steps),
        sigma_spec=noisy_spec)
    # phase 2: precision switch — backward/update to BF16, forward stays FP4
    cfg, tcfg, data = bundle["cfg"], bundle["tcfg"], bundle["data"]
    state = bundle["state"]
    qaf_cfg = fqt.QuantConfig(fwd_w=_NV, fwd_a=_NV)
    step_fn = make_train_step(cfg, qaf_cfg, tcfg)
    losses2 = []
    for step in range(switch_at, scale.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, m = step_fn(state, batch)
        losses2.append(float(m["loss"]))

    gap_before = _tail(losses1) - _tail(base_losses[:switch_at])
    gap_after = _tail(losses2) - _tail(base_losses)
    return [
        ("fig5_gap", "before_switch", gap_before),
        ("fig5_gap", "after_switch", gap_after),
        ("fig5_gap", "closed_fraction", 1.0 - gap_after /
         max(gap_before, 1e-9)),
        ("fig5_gnr", "at_switch", bundle["gnr"][-1]),
        ("fig5_gnr", "sqrt3_threshold", threshold.SQRT3),
    ]


# ---- Fig. 6 + Table 3: main experiment + QAF ------------------------------------------


def fig6_fqt_vs_bf16(scale: Optional[BenchScale] = None,
                     qaf_steps: int = 60):
    scale = scale or BenchScale(steps=200)
    # BF16 reference runs through the QAF horizon too (matched step counts)
    bf16_losses, bf16_bundle = train_loss_curve(
        fqt.bf16_config(),
        dataclasses.replace(scale, steps=scale.steps + qaf_steps,
                            sched_steps=scale.steps))
    fp4_losses, fp4_bundle = train_loss_curve(fqt.nvfp4_paper_config(),
                                              scale)

    # QAF phase: continue FP4 state with FP4-fwd/BF16-bwd + LR re-warm
    cfg, data = fp4_bundle["cfg"], fp4_bundle["data"]
    tcfg = fp4_bundle["tcfg"]
    qaf_tcfg = dataclasses.replace(
        tcfg, sched=schedule.ScheduleConfig(
            peak_lr=tcfg.sched.peak_lr * 0.5,
            warmup_steps=max(qaf_steps // 4, 1),
            total_steps=qaf_steps, min_lr_ratio=0.0,
            start_step=scale.steps))
    state = fp4_bundle["state"]
    # the step fn donates its input state — keep a copy for the eval below
    fp4_params = jax.tree.map(jnp.copy, state.params)
    state = jax.tree.map(jnp.copy, state)
    step_fn = make_train_step(cfg, fqt.qaf_config(), qaf_tcfg)
    qaf_losses = []
    for step in range(scale.steps, scale.steps + qaf_steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, m = step_fn(state, batch)
        qaf_losses.append(float(m["loss"]))

    # Table-3 proxy: held-out eval perplexity (synthetic stream)
    def eval_ppl(params, qcfg):
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=scale.seq,
                        global_batch=scale.batch, seed=1234 + scale.seed)
        tot = 0.0
        for b in make_eval_batches(dc, n=4):
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            loss, _ = registry.loss_fn(params, cfg, qcfg, batch, seed=0,
                                       remat=False)
            tot += float(loss)
        return float(np.exp(tot / 4))

    fp4_eval = fqt.qaf_config()          # deploy-time: FP4 forward
    rows = [
        ("fig6_final_loss", "bf16@200", _tail(bf16_losses[:scale.steps])),
        ("fig6_final_loss", "fp4@200", _tail(fp4_losses)),
        ("fig6_final_loss", "bf16@260", _tail(bf16_losses)),
        ("fig6_final_loss", "fp4+qaf@260", _tail(qaf_losses)),
        ("fig6_gap", "fp4_vs_bf16", _tail(fp4_losses)
         - _tail(bf16_losses[:scale.steps])),
        ("fig6_gap", "qaf_vs_bf16", _tail(qaf_losses)
         - _tail(bf16_losses)),
        ("table3_ppl", "bf16", eval_ppl(bf16_bundle["state"].params,
                                        fqt.bf16_config())),
        ("table3_ppl", "fp4", eval_ppl(fp4_params, fp4_eval)),
        ("table3_ppl", "fp4+qaf", eval_ppl(state.params, fp4_eval)),
    ]
    return rows


def table2_settings():
    """The quantization-settings comparison (static facts from the code)."""
    rows = []
    for name, mk in (("ours", fqt.nvfp4_paper_config),
                     ("wang2025", fqt.wang2025_config),
                     ("tseng2025", fqt.tseng2025_config)):
        qc = mk()
        n_fp4 = sum(getattr(qc, p) is not None for p in fqt.POINTS)
        rows.append(("table2_fp4_points", name, float(n_fp4)))
    return rows
