"""Render the §Roofline table from dry-run JSON records.

  PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]
  PYTHONPATH=src python -m benchmarks.roofline_report --md   # markdown

Columns: the three roofline terms (seconds), dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS (useful fraction), roofline-bound MFU, and peak
temp bytes/device from memory_analysis.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str, pattern: str = "*"):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, pattern + ".json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_ms(s):
    return f"{s*1e3:10.2f}" if s is not None else "         -"


def row(r, md=False):
    sep = " | " if md else "  "
    if r["status"] == "skip":
        return sep.join([f"{r['arch']:<22}", f"{r['shape']:<12}",
                         "SKIP: " + r["reason"][:60]])
    if r["status"] != "ok":
        return sep.join([f"{r['arch']:<22}", f"{r['shape']:<12}",
                         "ERROR: " + r.get("error", "")[:60]])
    rf = r["roofline"]
    uf = rf.get("useful_fraction")
    mfu = rf.get("mfu_bound")
    temp = (r["bytes_per_device"].get("temp") or 0) / 2 ** 30
    return sep.join([
        f"{r['arch']:<22}", f"{r['shape']:<12}", f"{r['kind']:<7}",
        fmt_ms(rf["t_compute"]), fmt_ms(rf["t_memory"]),
        fmt_ms(rf["t_collective"]), f"{rf['bottleneck']:<10}",
        f"{100*uf:6.1f}%" if uf else "     -",
        f"{100*mfu:6.2f}%" if mfu else "     -",
        f"{temp:8.2f}",
    ])


HEADER = ["arch", "shape", "kind", "t_comp(ms)", "t_mem(ms)", "t_coll(ms)",
          "bottleneck", "useful", "mfu_bound", "temp(GiB)"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--pattern", default="*1pod*")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    recs = load(args.dir, args.pattern)
    sep = " | " if args.md else "  "
    print(sep.join(f"{h:<12}" for h in HEADER))
    if args.md:
        print(sep.join(["---"] * len(HEADER)))
    for r in recs:
        print(row(r, args.md))
    ok = [r for r in recs if r["status"] == "ok"]
    print(f"\n{len(ok)} ok / {len(recs)} cells; bottleneck counts:",
          {b: sum(1 for r in ok if r['roofline']['bottleneck'] == b)
           for b in ("compute", "memory", "collective")})


if __name__ == "__main__":
    main()
