#!/usr/bin/env python3
"""Lower+compile one cell and print the top collective/memory ops
(hypothesis-forming tool for the §Perf loop).

  PYTHONPATH=src python tools/diagnose_cell.py --arch codeqwen1.5-7b \
      --shape train_4k [--moe-groups 16] [--act-mode sp]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
import argparse

from repro.launch import hlo_cost
from repro.launch.dryrun import run_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--qcfg", default="nvfp4")
    ap.add_argument("--act-mode", default="sp")
    ap.add_argument("--moe-groups", type=int, default=None)
    ap.add_argument("--dump", default=None, help="write compiled HLO here")
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    # reuse run_cell but keep the compiled text
    import repro.launch.dryrun as dr
    import repro.launch.specs as specs_mod
    from repro.configs import get_config
    from repro.core import fqt
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES_BY_NAME
    import dataclasses

    cfg = get_config(args.arch)
    if args.moe_groups is not None:
        cfg = dataclasses.replace(cfg, moe_groups=args.moe_groups)
    shape = SHAPES_BY_NAME[args.shape]
    qcfg = {"nvfp4": fqt.nvfp4_paper_config, "bf16": fqt.bf16_config,
            "qaf": fqt.qaf_config}[args.qcfg]()
    mesh = make_production_mesh()
    cell = specs_mod.build_cell(cfg, shape, mesh, qcfg=qcfg)
    cell.act_mode = None if args.act_mode == "off" else args.act_mode
    lowered = specs_mod.lower_cell(cell, mesh)
    compiled = lowered.compile()
    text = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(text)
    c = hlo_cost.analyze(text)
    print(f"flops/dev {c.flops:.3e}  bytes/dev {c.bytes:.3e}  "
          f"coll/dev {c.coll_bytes:.3e}")
    print(f"terms: comp {c.flops/197e12:.2f}s  mem {c.bytes/819e9:.2f}s  "
          f"coll {c.coll_bytes/50e9:.2f}s")
    mem = compiled.memory_analysis()
    print(f"temp/dev {mem.temp_size_in_bytes/2**30:.2f} GiB")
    print("\ntop ops (bytes x trips):")
    for nb, m, kind, typ, name in hlo_cost.top_ops(text, k=args.top):
        print(f"  {nb/2**30:9.2f}GiB x{m:5.0f} {kind:18s} {typ:40s} {name}")


if __name__ == "__main__":
    main()
