"""Environment preflight + docs-drift guard + serving self-check.

  PYTHONPATH=src python tools/check_env.py          # dependency report
  PYTHONPATH=src python tools/check_env.py --docs   # docs snippet check
  PYTHONPATH=src python tools/check_env.py --serve  # scheduler invariants
  PYTHONPATH=src python tools/check_env.py --traffic # workload/lifecycle
  PYTHONPATH=src python tools/check_env.py --spec   # speculative decoding
  PYTHONPATH=src python tools/check_env.py --mesh   # partition-spec check
  PYTHONPATH=src python tools/check_env.py --lint   # fp4lint AST invariants
  PYTHONPATH=src python tools/check_env.py --obs    # tracing/telemetry
  PYTHONPATH=src python tools/check_env.py --all    # every self-check

Default mode prints one line per dependency so a red test run can be
triaged at a glance instead of letting pytest collection explode on an
ImportError.  Optional deps have in-repo fallbacks (tests/_hyp.py for
hypothesis); missing REQUIRED deps exit non-zero.

``--docs`` scans README.md and docs/*.md fenced code blocks and verifies
they have not drifted from the code: every ``import``/``from repro...``
line must import (and every imported name must exist), every file path
mentioned in a command must exist, every ``--flag`` of a quoted command
must appear in the invoked module's source, every ``--bench NAME`` must
be a registered benchmark, and constructors named in ``KWARG_GUARDS``
(ServeConfig/Request/PrefixCache) must only be quoted with real
fields/parameters.  Wired into tier-1 as a fast test (tests/test_docs.py).

``--serve`` is a jax-free self-check of the serving scheduler's host
machinery: it builds a tiny refcounted page pool + prefix-cache radix
tree and drives a full submit/admit/grow/decode/free cycle, asserting
refcount conservation and that no page leaks.  Also tier-1
(tests/test_docs.py).

``--traffic`` is a host-side self-check of the traffic harness
(serve/workload.py + serve/metrics.py + the scheduler's chunked-prefill
and abort/timeout lifecycle): byte-for-byte workload determinism,
nearest-rank percentile math, page-pool conservation under cancellation
at every stage, and the per-tick-per-slot prefill chunk budget.  Also
tier-1 (tests/test_docs.py).

``--spec`` is a jax-free self-check of the speculative-decoding host
machinery (serve/metrics.py spec trajectory + the scheduler's spec
protocol): the greedy acceptance rule and rollback arithmetic (numpy
mirrors of the verify program), accepted-tokens/tick/slot percentiles,
ensure_capacity/advance_written bookkeeping, and partial-suffix
preemption's written/prompt invariant.  Also tier-1
(tests/test_docs.py).

``--mesh`` is a jax-free self-check of the sharded-serving partition-spec
layer (repro.distributed.specs): ``--mesh tp=N`` CLI grammar, the
code/scale congruence invariant of packed leaves, drop diagnostics for
odd dims, and the 4.5 bits/param packed wire accounting.  Also tier-1
(tests/test_docs.py).

``--lint`` runs fp4lint (repro.analysis, stdlib-ast, jax-free) over the
whole repo and fails on any finding outside tools/lint_baseline.txt or
any stale baseline entry — the static invariants (rounding policy, PRNG
stream discipline, PartitionSpec canonical form, trace hazards, packed
dtypes; see docs/lint.md).  Also tier-1 (tests/test_docs.py).

``--obs`` is a jax-free self-check of the observability layer
(repro.obs.trace + the scheduler's instrumentation): span balance
across the full request lifecycle (done / abort / timeout close the
request span; preemption keeps it open), counter conservation against
the scheduler's own stats and the page pool, the disabled tracer's
no-op contract, and the Chrome-trace-event exporter schema.  Also
tier-1 (tests/test_docs.py).

``--all`` runs every self-check above (docs, serve, traffic, spec, mesh,
lint, obs) plus the dependency report, and fails if any of them does.
"""
from __future__ import annotations

import importlib
import os
import re
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REQUIRED = ("jax", "jaxlib", "ml_dtypes", "numpy", "pytest")
OPTIONAL = {
    "hypothesis": "property tests fall back to tests/_hyp.py sweeps",
}

DOC_FILES = ("README.md", "docs/formats.md", "docs/serving.md",
             "docs/lint.md", "docs/observability.md")


def _probe(name: str):
    try:
        mod = importlib.import_module(name)
        return getattr(mod, "__version__", "?")
    except ImportError:
        return None


# ---- docs-drift check ---------------------------------------------------------


def _fenced_blocks(text: str):
    """Yield (lang, body) for every ``` fenced block."""
    for m in re.finditer(r"```(\w*)\n(.*?)```", text, re.DOTALL):
        yield m.group(1) or "", m.group(2)


def _check_import_line(line: str, errors: list, where: str):
    line = line.strip()
    m = re.match(r"from\s+([\w.]+)\s+import\s+(.+)", line)
    if m:
        mod_name, names = m.group(1), m.group(2)
        try:
            mod = importlib.import_module(mod_name)
        except Exception as e:                              # noqa: BLE001
            errors.append(f"{where}: cannot import {mod_name}: {e}")
            return
        # tolerate parenthesized import lists (possibly split across lines)
        names = names.split("#")[0].strip().strip("()\\,")
        for name in re.split(r"\s*,\s*", names):
            name = name.split(" as ")[0].strip().strip("()")
            if name and not hasattr(mod, name):
                errors.append(f"{where}: {mod_name} has no {name!r}")
        return
    m = re.match(r"import\s+([\w.]+)", line)
    if m:
        try:
            importlib.import_module(m.group(1))
        except Exception as e:                              # noqa: BLE001
            errors.append(f"{where}: cannot import {m.group(1)}: {e}")


# Serving-knob drift guard: docs quoting these constructors must only use
# real dataclass fields / signature parameters (catches knob renames —
# e.g. ServeConfig.page_size or PrefixCache.max_pages going away while
# docs still advertise them).
KWARG_GUARDS = {
    "ServeConfig": ("repro.serve", "ServeConfig"),
    "Request": ("repro.serve", "Request"),
    "PrefixCache": ("repro.serve", "PrefixCache"),
    "WorkloadConfig": ("repro.serve", "WorkloadConfig"),
    "TenantSpec": ("repro.serve", "TenantSpec"),
}


def _guarded_fields(cls) -> set:
    """Accepted keyword names of a guarded constructor: dataclass fields,
    or (plain classes like PrefixCache) the __init__ signature."""
    import dataclasses
    import inspect
    if dataclasses.is_dataclass(cls):
        return {f.name for f in dataclasses.fields(cls)}
    return {p for p in inspect.signature(cls).parameters if p != "self"}


def _check_guarded_kwargs(body: str, errors: list, where: str):
    for name, (mod_name, attr) in KWARG_GUARDS.items():
        hits = re.finditer(
            name + r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)", body)
        # strip string literals (a mesh="tp=2" value must not read as a
        # tp= kwarg) and nested call arguments (np.array(x, dtype=...))
        # so neither contributes phantom kwargs
        args = [re.sub(r"\([^()]*\)", "",
                       re.sub(r"'[^']*'|\"[^\"]*\"", "''", m.group(1)))
                for m in hits]
        kwargs = {kw for a in args
                  for kw in re.findall(r"(?<![\w.])(\w+)\s*=", a)}
        if not kwargs:
            continue
        try:
            cls = getattr(importlib.import_module(mod_name), attr)
            fields = _guarded_fields(cls)
        except Exception as e:                              # noqa: BLE001
            errors.append(f"{where}: cannot resolve {mod_name}.{attr}: {e}")
            continue
        for kw in sorted(kwargs - fields):
            errors.append(f"{where}: {name} has no field {kw!r} "
                          f"(have {sorted(fields)})")


def _module_source(modpath: str):
    """Best-effort source file of ``python -m modpath`` within the repo."""
    for base in ("src", "."):
        cand = os.path.join(REPO_ROOT, base, *modpath.split(".")) + ".py"
        if os.path.exists(cand):
            return cand
        pkg = os.path.join(REPO_ROOT, base, *modpath.split("."),
                           "__main__.py")
        if os.path.exists(pkg):
            return pkg
    return None


def _check_command(cmd: str, errors: list, where: str):
    """One shell command quoting this repo: paths, flags, bench names."""
    toks = cmd.split()
    src_file = None
    if "-m" in toks and toks.index("-m") + 1 < len(toks):
        modpath = toks[toks.index("-m") + 1]
        if modpath != "pytest":
            src_file = _module_source(modpath)
            if src_file is None:
                errors.append(f"{where}: module {modpath} not found")
    for t in toks:
        if re.fullmatch(r"[\w./-]+\.(py|md)", t):
            if not os.path.exists(os.path.join(REPO_ROOT, t)):
                errors.append(f"{where}: referenced file {t} missing")
            elif t.endswith(".py") and src_file is None:
                src_file = os.path.join(REPO_ROOT, t)
    if src_file:
        src = open(src_file).read()
        for t in toks:
            if t.startswith("--") and re.fullmatch(r"--[\w-]+", t):
                if t not in src:
                    errors.append(f"{where}: {os.path.relpath(src_file, REPO_ROOT)} "
                                  f"does not define flag {t}")
    if "--bench" in toks and toks.index("--bench") + 1 < len(toks):
        bench = toks[toks.index("--bench") + 1]
        sys.path.insert(0, REPO_ROOT)
        try:
            from benchmarks.run import BENCHES
            if bench not in BENCHES:
                errors.append(f"{where}: unknown bench {bench!r} "
                              f"(have {sorted(BENCHES)})")
        finally:
            sys.path.pop(0)
    if "--mesh" in toks and toks.index("--mesh") + 1 < len(toks):
        # quoted mesh specs must parse (jax-free: repro.distributed.specs)
        spec = toks[toks.index("--mesh") + 1].strip("'\"")
        sys.path.insert(0, os.path.join(REPO_ROOT, "src"))
        try:
            from repro.distributed.specs import parse_mesh_spec
            try:
                parse_mesh_spec(spec)
            except ValueError as e:
                errors.append(f"{where}: bad --mesh spec {spec!r}: {e}")
        finally:
            sys.path.pop(0)


def check_docs() -> int:
    """Verify README/docs code snippets against the code.  0 = no drift."""
    errors = []
    for rel in DOC_FILES:
        path = os.path.join(REPO_ROOT, rel)
        if not os.path.exists(path):
            errors.append(f"{rel}: missing")
            continue
        text = open(path).read()
        for lang, body in _fenced_blocks(text):
            # join backslash-continued command lines
            body = re.sub(r"\\\n\s*", " ", body)
            for ln, line in enumerate(body.splitlines(), 1):
                where = f"{rel} (block line {ln})"
                if lang in ("python", "py", ""):
                    if re.match(r"\s*(from|import)\s", line):
                        _check_import_line(line, errors, where)
                if lang in ("bash", "sh", "shell", ""):
                    if re.search(r"\bpython3?\b", line):
                        _check_command(line.strip(), errors, where)
            if lang in ("python", "py"):
                _check_guarded_kwargs(body, errors, f"{rel} (block)")
        # markdown links to local files must resolve
        for m in re.finditer(r"\]\(([\w./-]+\.md)\)", text):
            tgt = os.path.normpath(os.path.join(os.path.dirname(path),
                                                m.group(1)))
            if not os.path.exists(tgt):
                errors.append(f"{rel}: broken link {m.group(1)}")
    if errors:
        for e in errors:
            print(f"DRIFT    {e}")
        print(f"FATAL: {len(errors)} docs drift error(s)")
        return 1
    print(f"ok       docs snippets in sync ({', '.join(DOC_FILES)})")
    return 0


# ---- serving scheduler self-check ---------------------------------------------


def check_serve() -> int:
    """Host-side (jax-free) invariants of the serving scheduler stack:
    refcount conservation in the page pool, radix-tree bookkeeping, and
    no page leaked after a full submit/admit/grow/decode/free cycle."""
    for base in ("src",):
        p = os.path.join(REPO_ROOT, base)
        if p not in sys.path:
            sys.path.insert(0, p)
    import numpy as np
    from repro.serve.prefix_cache import PrefixCache
    from repro.serve.scheduler import PagePool, Request, Scheduler

    errors = []

    def conserved(pool, what):
        if pool.free_pages + pool.pages_in_use != pool.total_pages - 1:
            errors.append(
                f"{what}: refcount conservation broken "
                f"(free {pool.free_pages} + in-use {pool.pages_in_use} "
                f"!= {pool.total_pages - 1})")

    # pool: alloc/ref/free conservation + hardening
    pool = PagePool(9)
    pages = pool.alloc(4)
    pool.ref(pages[0])
    pool.free([pages[0]])
    conserved(pool, "pool after shared free")
    pool.free(pages)
    conserved(pool, "pool after full free")
    for bad, tag in (([pages[0]], "double free"), ([0], "trash"),
                     ([42], "out of range")):
        try:
            pool.free(bad)
            errors.append(f"pool accepted {tag}")
        except ValueError:
            pass

    # radix tree over a fresh pool: insert/match/evict
    pool = PagePool(9)
    pc = PrefixCache(pool, page_size=4)
    toks = np.arange(12)
    row = pool.alloc(3)
    pc.insert(toks, row)
    pool.free(row)                       # cache's refs keep pages alive
    conserved(pool, "tree after slot free")
    if pc.match(toks) != row:
        errors.append("radix tree did not match its own insert")
    if pc.match(np.arange(1, 13)) != []:
        errors.append("radix tree matched a different prefix")
    if pc.evict(3) != 3 or pool.free_pages != pool.total_pages - 1:
        errors.append("eviction leaked pages")

    # full scheduler cycle: submit/admit/grow/decode/free, warm reuse
    sched = Scheduler(n_slots=2, max_len=32, page_size=4,
                      prefix_cache=True)
    prompt = np.arange(10)
    for rid in range(3):
        sched.submit(Request(rid, prompt, max_new=6, arrival=0))
    placed = sched.admit(0)
    if [p[3] for p in placed] != [0, 8]:
        errors.append(f"expected cold then 8-token warm admission, got "
                      f"{[p[3] for p in placed]}")
    tick = 0
    while sched.has_work() and tick < 50:
        sched.admit(tick)
        T = sched.tick_steps(4, {s: 1 for s in sched.active_slots()})
        sched.ensure_capacity(T)
        for s in list(sched.active_slots()):
            sched.commit(s, np.full((max(T, 1),), 7), eos_id=-1)
        sched.count_tick(T)
        tick += 1
    if sched.stats["completed"] != 3:
        errors.append(f"cycle did not complete: {sched.stats}")
    conserved(sched.pool, "scheduler after cycle")
    live = sched.pool.pages_in_use - sched.prefix_cache.cached_pages
    if live != 0:
        errors.append(f"{live} pages leaked past the prefix cache after "
                      f"all slots freed")
    if sched.prefix_cache.evict(sched.prefix_cache.cached_pages) < 1 or \
            sched.pool.pages_in_use != 0:
        errors.append("draining the prefix cache left pages in use")

    if errors:
        for e in errors:
            print(f"SERVE    {e}")
        print(f"FATAL: {len(errors)} serving invariant error(s)")
        return 1
    print("ok       serving scheduler invariants (pool refcounts, radix "
          "tree, admit/grow/free cycle)")
    return 0


# ---- traffic harness self-check ----------------------------------------------


def check_traffic() -> int:
    """Host-side invariants of the traffic harness (serve/workload.py,
    serve/metrics.py, and the scheduler's chunked-prefill/lifecycle
    machinery — no engine, no device): workload determinism byte-for-
    byte, nearest-rank percentile math, and the request-lifecycle state
    machine (abort/timeout at every stage conserves the page pool; at
    most prefill_chunk prompt tokens per slot per tick)."""
    for base in ("src",):
        p = os.path.join(REPO_ROOT, base)
        if p not in sys.path:
            sys.path.insert(0, p)
    import numpy as np
    from repro.serve.metrics import MetricsRecorder, percentile
    from repro.serve.scheduler import Request, Scheduler
    from repro.serve.workload import (TenantSpec, WorkloadConfig,
                                      generate_workload, trace_fingerprint)

    errors = []

    # workload generator: deterministic byte-for-byte, seed-sensitive
    wcfg = WorkloadConfig(tenants=(
        TenantSpec("chat", rate=0.6, prompt_lens=(4, 8),
                   system_prompt_len=4, deadline_slack=16),
        TenantSpec("batch", rate=0.3, prompt_lens=(12,), abort_prob=0.3,
                   timeout=20, burst_every=6, burst_size=1),
    ), ticks=20, seed=3)
    a, b = generate_workload(wcfg), generate_workload(wcfg)
    if trace_fingerprint(a) != trace_fingerprint(b):
        errors.append("workload trace not deterministic for a fixed seed")
    import dataclasses
    c = generate_workload(dataclasses.replace(wcfg, seed=4))
    if trace_fingerprint(a) == trace_fingerprint(c):
        errors.append("workload trace identical across different seeds")
    if [e.rid for e in a] != list(range(len(a))):
        errors.append("workload rids not sequential in arrival order")
    if any(a[i].arrival > a[i + 1].arrival for i in range(len(a) - 1)):
        errors.append("workload events not sorted by arrival")

    # nearest-rank percentile math (no interpolation, ever)
    vals = [10, 20, 30, 40]
    for p, want in ((50, 20), (75, 30), (95, 40), (99, 40), (100, 40)):
        got = percentile(vals, p)
        if got != want:
            errors.append(f"percentile({p}) = {got}, want {want}")
    if percentile([7], 50) != 7:
        errors.append("percentile of a singleton is not the singleton")
    rec = MetricsRecorder()
    rec.submitted(0, arrival=2, deadline=10)
    rec.admitted(0, 3)
    rec.first_token(0, 5)
    rec.finished(0, 9, ntokens=5)
    rec.submitted(1, arrival=2, deadline=4)
    rec.first_token(1, 6)
    rec.finished(1, 8, ntokens=3)
    s = rec.summary()
    if s["ttft_ticks"]["p50"] != 3 or s["ttft_ticks"]["max"] != 4:
        errors.append(f"TTFT summary wrong: {s['ttft_ticks']}")
    if s["tpot_ticks"]["p50"] != 1.0:
        errors.append(f"TPOT summary wrong: {s['tpot_ticks']}")
    if s["goodput"] != 0.5:           # rid 1 finished past its deadline
        errors.append(f"goodput {s['goodput']} != 0.5")

    # lifecycle state machine: abort/timeout at every stage conserves the
    # pool; chunked prefill never exceeds its per-tick-per-slot budget
    def conserved(sched, what):
        pool = sched.pool
        if pool.free_pages + pool.pages_in_use != pool.total_pages - 1:
            errors.append(f"{what}: pool conservation broken")

    C = 3
    sched = Scheduler(n_slots=2, max_len=32, page_size=4,
                      prefill_chunk=C)
    rng = np.random.default_rng(0)
    sched.submit(Request(0, rng.integers(0, 99, 10), max_new=4))
    sched.submit(Request(1, rng.integers(0, 99, 9), max_new=4,
                         abort_at=1))                   # dies mid-prefill
    sched.submit(Request(2, rng.integers(0, 99, 6), max_new=4,
                         arrival=0, timeout=1))         # dies queued
    tick = 0
    while sched.has_work() and tick < 30:
        sched.expire(tick)
        sched.admit(tick)
        sched.prefill_work(tick)
        T = sched.tick_steps(4, {})
        sched.ensure_capacity(T)
        for s_ in list(sched.decoding_slots()):
            if T:
                sched.commit(s_, np.full((T,), 7), eos_id=-1)
        conserved(sched, f"tick {tick}")
        tick += 1
    if sorted(sched.cancelled) != [1, 2]:
        errors.append(f"expected rids 1,2 cancelled, got "
                      f"{sorted(sched.cancelled)}")
    stages = {r: v["stage"] for r, v in sched.cancelled.items()}
    if stages.get(1) != "prefill" or stages.get(2) != "queued":
        errors.append(f"wrong cancel stages: {stages}")
    if 0 not in sched.results:
        errors.append("surviving request did not complete")
    if sched.pool.pages_in_use != 0:
        errors.append(f"{sched.pool.pages_in_use} pages leaked after the "
                      f"lifecycle cycle")
    per_tick = {}
    for t, s_, _, clen in sched.prefill_log:
        per_tick[(t, s_)] = per_tick.get((t, s_), 0) + clen
        if clen > C:
            errors.append(f"chunk of {clen} tokens exceeds prefill_chunk "
                          f"{C} at tick {t}")
    if per_tick and max(per_tick.values()) > C:
        errors.append("a slot prefilled more than one chunk in a tick")
    # cancel() mid-decode on a fresh scheduler
    sched = Scheduler(n_slots=1, max_len=32, page_size=4)
    sched.submit(Request(5, np.arange(6), max_new=8))
    sched.admit(0)
    sched.ensure_capacity(2)
    sched.commit(0, np.full((2,), 9), eos_id=-1)
    if not sched.cancel(5, reason="abort"):
        errors.append("cancel() did not find a decoding request")
    if sched.cancelled[5]["stage"] != "decode" or \
            len(sched.cancelled[5]["tokens"]) != 2:
        errors.append(f"decode-stage cancel wrong: {sched.cancelled[5]}")
    if sched.pool.pages_in_use != 0:
        errors.append("cancel() leaked pages")
    if sched.cancel(99):
        errors.append("cancel() accepted an unknown rid")

    if errors:
        for e in errors:
            print(f"TRAFFIC  {e}")
        print(f"FATAL: {len(errors)} traffic harness error(s)")
        return 1
    print("ok       traffic harness (workload determinism, nearest-rank "
          "percentiles, lifecycle conservation, chunk budget)")
    return 0


# ---- speculative decoding self-check ------------------------------------------


def check_spec() -> int:
    """Host-side (jax-free) invariants of the speculative-decoding
    machinery: the greedy acceptance rule (longest matching prefix via
    a cumulative product of per-position agreement, plus one corrected
    token — 1..k emitted, always), the rollback arithmetic the verify
    program applies to cache lengths, the accepted-tokens metrics
    trajectory, and the scheduler's spec protocol (ensure_capacity
    without the written advance, then advance_written by the ACCEPTED
    length) including partial-suffix preemption's written/prompt
    bookkeeping."""
    for base in ("src",):
        p = os.path.join(REPO_ROOT, base)
        if p not in sys.path:
            sys.path.insert(0, p)
    import numpy as np
    from repro.serve.metrics import MetricsRecorder
    from repro.serve.scheduler import Request, Scheduler

    errors = []

    # acceptance rule: numpy mirror of the verify program's
    # acc = sum(cumprod(match)) — longest agreeing prefix, then +1
    def n_emit(drafts, greedy):
        match = (np.asarray(greedy[:-1]) == np.asarray(drafts)).astype(int)
        return int(np.cumprod(match).sum()) + 1

    for drafts, greedy, want in (
            ([5, 6, 7], [5, 6, 7, 8], 4),      # all accepted: k tokens
            ([5, 6, 7], [5, 6, 9, 8], 3),      # 2 drafts + correction
            ([5, 6, 7], [9, 6, 7, 8], 1),      # first draft wrong
            ([5, 6, 7], [5, 9, 7, 8], 2),      # later agreement ignored
            ([], [4], 1)):                     # k=1 degenerate: decode
        got = n_emit(drafts, greedy)
        if got != want:
            errors.append(f"acceptance({drafts}, {greedy}) = {got}, "
                          f"want {want}")
    # rollback arithmetic: lengths advance by k at write, shrink to
    # base + n_emit — equivalently += n_emit - k, and 1 <= n_emit <= k
    for k in (2, 3, 4):
        for acc in range(k):
            ne = acc + 1
            base, after = 37, 37 + k
            rolled = after + (ne - k)
            if not base + 1 <= rolled <= base + k:
                errors.append(f"rollback k={k} acc={acc}: length {rolled} "
                              f"outside (base, base+k]")

    # metrics: the accepted-tokens/tick/slot trajectory and rate
    rec = MetricsRecorder()
    rec.spec_tick([3, 1], k=3)
    rec.spec_tick([2], k=3)
    s = rec.summary()
    acc = s.get("spec_accepted_per_tick_slot", {})
    if acc.get("n") != 3 or acc.get("max") != 3 or acc.get("p50") != 2:
        errors.append(f"spec accepted summary wrong: {acc}")
    rate = s.get("spec_acceptance_rate", {})
    if rate.get("max") != 1.0 or rate.get("p50") != 0.5:
        errors.append(f"spec acceptance-rate summary wrong: {rate}")
    if "spec_accepted_per_tick_slot" in MetricsRecorder().summary():
        errors.append("spec metrics reported for a non-spec trace")

    # scheduler spec protocol: grow for k candidate rows WITHOUT the
    # written advance, then advance by the accepted length only
    k = 3
    sched = Scheduler(n_slots=1, max_len=32, page_size=4)
    sched.submit(Request(0, np.arange(10), max_new=9))
    sched.admit(0)
    st = sched.slots[0]
    if st.written != 10:
        errors.append(f"admission written {st.written} != plen")
    sched.ensure_capacity(k, advance=False)
    if st.written != 10:
        errors.append("ensure_capacity(advance=False) advanced written")
    for ne, want in ((2, 12), (3, 15)):
        sched.ensure_capacity(k, advance=False)
        sched.advance_written(0, ne)
        if st.written != want:
            errors.append(f"advance_written: written {st.written} != {want}")
        sched.commit(0, np.full((ne,), 7), eos_id=-1)
    if sched.pool.free_pages + sched.pool.pages_in_use \
            != sched.total_pages - 1:
        errors.append("spec protocol broke pool conservation")

    # partial-suffix preemption bookkeeping: the requeued effective
    # prompt carries written + 1 tokens (the last committed token's row
    # is not in the pages yet); the adopted pages cover exactly written
    sched = Scheduler(n_slots=1, max_len=32, page_size=4,
                      prefix_cache=True)
    sched.submit(Request(1, np.arange(8), max_new=12))
    sched.admit(0)
    st = sched.slots[0]
    sched.commit(0, np.asarray([7]), eos_id=-1)   # prefill-sampled token:
    # committed WITHOUT a written advance (its row lands next tick)
    sched.ensure_capacity(k, advance=False)
    sched.advance_written(0, 3)
    sched.commit(0, np.asarray([7, 7, 7]), eos_id=-1)
    written = st.written
    sched._preempt(0)
    req = sched.queue[0]
    if len(req.prompt) != written + 1:
        errors.append(f"preempted effective prompt {len(req.prompt)} "
                      f"!= written + 1 ({written + 1})")
    if sched.prefix_cache.cached_pages != written // 4:
        errors.append(f"adopted pages {sched.prefix_cache.cached_pages} "
                      f"!= written // page_size ({written // 4})")
    placed = sched.admit(1)
    if not placed or placed[0][3] != written // 4 * 4:
        errors.append(f"resume did not share the adopted full pages: "
                      f"{placed}")
    if list(sched.slots[0].tokens) != [7, 7, 7, 7]:
        errors.append(f"resume lost committed tokens: "
                      f"{sched.slots[0].tokens}")

    if errors:
        for e in errors:
            print(f"SPEC     {e}")
        print(f"FATAL: {len(errors)} speculative-decoding error(s)")
        return 1
    print("ok       speculative decoding (greedy acceptance rule, rollback "
          "arithmetic, accepted-tokens metrics, scheduler spec protocol, "
          "partial-suffix resume)")
    return 0


# ---- mesh spec self-check -----------------------------------------------------


def check_mesh() -> int:
    """Jax-free self-check of the packed-serving partition-spec layer
    (repro.distributed.specs): the mesh-spec CLI grammar, and the
    code/scale CONGRUENCE invariant — a mesh axis shards logical dim d of
    the block scales iff it shards dim d of the nibble codes, for every
    weight kind x shape x TP size, with odd dims diagnosed (never silently
    replicated) and the wire-format accounting at its closed form."""
    for base in ("src",):
        p = os.path.join(REPO_ROOT, base)
        if p not in sys.path:
            sys.path.insert(0, p)
    from repro.distributed import specs

    errors = []

    # CLI grammar
    for spec, want in (
            (None, {"model": 1}), ("", {"model": 1}),
            ("tp=2", {"model": 2}), ("tp=4", {"model": 4}),
            ("dp=2,tp=4", {"data": 2, "model": 4}),
            ("fsdp=2", {"data": 2, "model": 1})):
        got = specs.parse_mesh_spec(spec)
        if got != want:
            errors.append(f"parse_mesh_spec({spec!r}) = {got}, want {want}")
    for bad in ("tp=0", "tp=-1", "ep=2", "tp", "tp=2;dp=2"):
        try:
            specs.parse_mesh_spec(bad)
            errors.append(f"parse_mesh_spec accepted {bad!r}")
        except ValueError:
            pass

    # congruence sweep: every kind x shape x tp size keeps scale specs
    # derived from (== congruent with) code specs
    kinds = {                       # logical base specs, Megatron rules
        "io": (None, "model"), "oi": ("model", None),
        "d_vocab": (None, "model"), "stacked_io": (None, None, "model"),
    }
    shapes = ((64, 32), (64, 48), (48, 64), (2, 64, 32), (17, 30))
    for tp in (1, 2, 4):
        sizes = {"model": tp}
        for kname, base in kinds.items():
            for shape in shapes:
                if len(base) != len(shape):
                    continue
                drops = []
                out = specs.packed_leaf_specs(
                    base, shape, axis=-2, block=16, axis_sizes=sizes,
                    path=f"{kname}{shape}", drops=drops)
                if not specs.congruent(out["packed"], out["scales"]):
                    errors.append(
                        f"{kname}{shape} tp={tp}: scales "
                        f"{out['scales']} not congruent with codes "
                        f"{out['packed']}")
                sharded = any(a is not None for a in out["packed"])
                if tp > 1 and not sharded and not drops:
                    errors.append(
                        f"{kname}{shape} tp={tp}: fully replicated "
                        f"without a drop diagnostic")

    # odd dims must be DIAGNOSED, not silently replicated
    drops = []
    specs.packed_leaf_specs((None, "model"), (64, 30), axis=-2, block=16,
                            axis_sizes={"model": 4}, path="w_odd",
                            drops=drops)
    if not drops or "w_odd" not in drops[0]:
        errors.append(f"odd-dim drop not diagnosed by path: {drops}")
    drops = []
    specs.divisible_axes(("model",), (30,), {"model": 4}, path="leaf_odd",
                         drops=drops)
    if not drops or "leaf_odd" not in drops[0]:
        errors.append(f"divisible_axes drop not diagnosed: {drops}")

    # wire-format accounting: NVFP4 block 16 == 4.5 bits/param exactly
    bits = specs.packed_wire_bits_per_param()
    if bits != 4.5:
        errors.append(f"packed wire bits/param {bits} != 4.5")
    ratio = specs.packed_gather_ratio()
    if abs(ratio - 16 / 4.5) > 1e-12:
        errors.append(f"packed gather ratio {ratio} != {16 / 4.5}")

    if errors:
        for e in errors:
            print(f"MESH     {e}")
        print(f"FATAL: {len(errors)} mesh spec error(s)")
        return 1
    print("ok       mesh partition specs (CLI grammar, code/scale "
          "congruence, drop diagnostics, 4.5 bits/param wire accounting)")
    return 0


# ---- fp4lint self-check -------------------------------------------------------


def check_lint() -> int:
    """Run fp4lint over the repo scan set and diff the baseline exactly.
    Jax-free (repro.analysis is pure stdlib), so this runs even when the
    accelerator stack is broken."""
    for base in ("src",):
        p = os.path.join(REPO_ROOT, base)
        if p not in sys.path:
            sys.path.insert(0, p)
    from repro.analysis import baseline_diff, lint_paths, load_baseline

    findings, stats = lint_paths(root=REPO_ROOT)
    baseline = load_baseline(
        os.path.join(REPO_ROOT, "tools", "lint_baseline.txt"))
    new, stale = baseline_diff(findings, baseline)
    for f in new:
        print(f"LINT     {f.render()}")
    for key in stale:
        print(f"LINT     stale baseline entry: {key}")
    if new or stale:
        print(f"FATAL: {len(new)} new finding(s), {len(stale)} stale "
              f"baseline entr{'y' if len(stale) == 1 else 'ies'} "
              f"(python tools/lint.py for details)")
        return 1
    per_rule = ", ".join(f"{k}={v}" for k, v in
                         sorted(stats.per_rule.items())) or "0 findings"
    print(f"ok       fp4lint ({stats.files_scanned} files, {per_rule}, "
          f"{stats.suppressed} pragma-suppressed, baseline exact, "
          f"{stats.runtime_s * 1e3:.0f} ms)")
    return 0


# ---- observability self-check -------------------------------------------------


def check_obs() -> int:
    """Jax-free self-check of the observability layer (repro.obs.trace +
    the serving scheduler's instrumentation): the tracer's span-balance
    accounting, the disabled tracer's no-op contract, a full request
    lifecycle (completion, mid-prefill abort, queued timeout) with span
    balance and counter conservation against the scheduler's own stats
    and page pool, preemption keeping the request span open, and the
    Chrome-trace-event exporter schema."""
    for base in ("src",):
        p = os.path.join(REPO_ROOT, base)
        if p not in sys.path:
            sys.path.insert(0, p)
    import json
    import tempfile

    import numpy as np
    from repro.obs.trace import (NULL_TRACER, Counters, Tracer, load_trace,
                                 validate_events)
    from repro.serve.scheduler import Request, Scheduler

    errors = []

    # tracer unit: simulated clock, span balance, counter accumulation
    trc = Tracer(clock="tick", process="check")
    trc.set_time(3)
    trc.begin("t", "work")
    trc.counter("n", 2)
    trc.counter("n")
    trc.gauge("depth", 7)
    trc.instant("t", "mark")
    trc.end("t", "work")
    if trc.counters["n"] != 3:
        errors.append(f"counter accumulation: n = {trc.counters['n']} != 3")
    if trc.spans_opened != 1 or trc.spans_closed != 1 or trc.open_spans():
        errors.append(f"span accounting broken: {trc.spans_opened} opened, "
                      f"{trc.spans_closed} closed, {trc.open_spans()} open")
    if any(e["ts"] != 3 for e in trc.trace_events() if e["ph"] != "M"):
        errors.append("set_time(3) did not stamp every event at ts=3")

    # disabled tracer: inert, records nothing, refuses to export
    if NULL_TRACER.enabled:
        errors.append("NULL_TRACER claims to be enabled")
    NULL_TRACER.begin("t", "x")
    if NULL_TRACER.counter("n", 5) != 0 or NULL_TRACER.n_events != 0:
        errors.append("NULL_TRACER recorded something")
    try:
        NULL_TRACER.export("/dev/null")
        errors.append("NULL_TRACER.export did not refuse")
    except RuntimeError:
        pass

    # counter substrate keeps the mapping protocol MetricsRecorder uses
    c = Counters({"a": 1})
    c.inc("a", 2)
    if dict(c) != {"a": 3} or "a" not in c or len(c) != 1:
        errors.append(f"Counters mapping protocol broken: {c!r}")

    # full lifecycle with a tracer attached: one completion, one abort
    # mid-prefill, one timeout while queued — every request span closes,
    # and the tracer's counters agree with the scheduler's stats
    trc = Tracer(clock="tick")
    sched = Scheduler(n_slots=2, max_len=32, page_size=4, prefill_chunk=3,
                      tracer=trc)
    rng = np.random.default_rng(0)
    sched.submit(Request(0, rng.integers(0, 99, 10), max_new=4))
    sched.submit(Request(1, rng.integers(0, 99, 9), max_new=4, abort_at=1))
    sched.submit(Request(2, rng.integers(0, 99, 6), max_new=4, arrival=0,
                         timeout=1))
    tick = 0
    while sched.has_work() and tick < 30:
        sched.expire(tick)
        sched.admit(tick)
        sched.prefill_work(tick)
        T = sched.tick_steps(4, {})
        sched.ensure_capacity(T)
        for s_ in list(sched.decoding_slots()):
            if T:
                sched.commit(s_, np.full((T,), 7), eos_id=-1)
        tick += 1
    cnt = trc.counters
    if trc.spans_opened != 3 or trc.open_spans():
        errors.append(f"lifecycle spans unbalanced: {trc.spans_opened} "
                      f"opened, {trc.open_spans()} still open at drain")
    for cname, sname in (("sched_admitted", "admitted"),
                         ("sched_completed", "completed"),
                         ("sched_cancelled", "cancelled")):
        if cnt.get(cname) != sched.stats[sname]:
            errors.append(f"{cname} = {cnt.get(cname)} disagrees with "
                          f"scheduler stats {sname} = {sched.stats[sname]}")
    alloc = (cnt.get("pages_private") + cnt.get("pages_shared")
             + cnt.get("pages_demand"))
    if alloc != cnt.get("pages_released") or sched.pool.pages_in_use != 0:
        errors.append(f"page counters not conserved at drain: "
                      f"{alloc} allocated != {cnt.get('pages_released')} "
                      f"released ({sched.pool.pages_in_use} still in use)")

    # preemption keeps the request span OPEN (resume is the same request)
    ptrc = Tracer(clock="tick")
    psched = Scheduler(n_slots=1, max_len=32, page_size=4,
                       prefix_cache=True, tracer=ptrc)
    psched.submit(Request(7, np.arange(8), max_new=12))
    psched.admit(0)
    psched.commit(0, np.asarray([9]), eos_id=-1)
    psched._preempt(0)
    if ptrc.open_spans() != {("req:7", "request"): 1}:
        errors.append(f"preemption closed the request span: "
                      f"{ptrc.open_spans()}")
    if ptrc.counters.get("sched_preempted") != 1:
        errors.append("preemption did not bump sched_preempted")

    # exporter round-trip: valid Chrome trace-event JSON, object form
    fd, path = tempfile.mkstemp(suffix=".json")
    os.close(fd)
    try:
        trc.export(path)
        with open(path) as f:
            doc = json.load(f)
        if not isinstance(doc, dict) or "traceEvents" not in doc:
            errors.append("export is not the traceEvents object form")
        events = load_trace(path)
        problems = validate_events(events)
        for pr in problems[:5]:
            errors.append(f"exported event invalid: {pr}")
        if len(events) != len(trc.trace_events()):
            errors.append("export dropped events")
        if doc.get("otherData", {}).get("clock") != "tick":
            errors.append("export lost the clock annotation")
    finally:
        os.unlink(path)

    if errors:
        for e in errors:
            print(f"OBS      {e}")
        print(f"FATAL: {len(errors)} observability error(s)")
        return 1
    print("ok       observability (span balance, counter conservation vs "
          "scheduler stats, no-op tracer contract, Chrome trace schema)")
    return 0


# ---- dependency report --------------------------------------------------------


def check_deps() -> int:
    print(f"python {sys.version.split()[0]}")
    missing_required = []
    for name in REQUIRED:
        ver = _probe(name)
        if ver is None:
            missing_required.append(name)
            print(f"MISSING  {name}  (required)")
        else:
            print(f"ok       {name} {ver}")
    for name, fallback in OPTIONAL.items():
        ver = _probe(name)
        if ver is None:
            print(f"absent   {name}  (optional; {fallback})")
        else:
            print(f"ok       {name} {ver}")
    try:
        import jax
        print(f"backend  {jax.default_backend()} "
              f"({len(jax.devices())} device(s))")
        if _probe("jax") and not hasattr(jax, "shard_map"):
            print("note     jax.shard_map absent -> "
                  "repro.distributed.compat fallback in use")
    except Exception as e:                                  # noqa: BLE001
        print(f"backend  probe failed: {e}")
    if missing_required:
        print(f"FATAL: missing required deps: {missing_required}")
        return 1
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--all" in argv:
        rc = 0
        for check in (check_docs, check_serve, check_traffic, check_spec,
                      check_mesh, check_lint, check_obs, check_deps):
            rc |= check()
        return rc
    if "--docs" in argv:
        return check_docs()
    if "--serve" in argv:
        return check_serve()
    if "--traffic" in argv:
        return check_traffic()
    if "--spec" in argv:
        return check_spec()
    if "--mesh" in argv:
        return check_mesh()
    if "--lint" in argv:
        return check_lint()
    if "--obs" in argv:
        return check_obs()
    return check_deps()


if __name__ == "__main__":
    sys.exit(main())
