"""Environment preflight: report versions and missing OPTIONAL deps.

  PYTHONPATH=src python tools/check_env.py

Prints one line per dependency so a red test run can be triaged at a
glance instead of letting pytest collection explode on an ImportError.
Optional deps have in-repo fallbacks (tests/_hyp.py for hypothesis);
missing REQUIRED deps exit non-zero.
"""
from __future__ import annotations

import importlib
import sys

REQUIRED = ("jax", "jaxlib", "ml_dtypes", "numpy", "pytest")
OPTIONAL = {
    "hypothesis": "property tests fall back to tests/_hyp.py sweeps",
}


def _probe(name: str):
    try:
        mod = importlib.import_module(name)
        return getattr(mod, "__version__", "?")
    except ImportError:
        return None


def main() -> int:
    print(f"python {sys.version.split()[0]}")
    missing_required = []
    for name in REQUIRED:
        ver = _probe(name)
        if ver is None:
            missing_required.append(name)
            print(f"MISSING  {name}  (required)")
        else:
            print(f"ok       {name} {ver}")
    for name, fallback in OPTIONAL.items():
        ver = _probe(name)
        if ver is None:
            print(f"absent   {name}  (optional; {fallback})")
        else:
            print(f"ok       {name} {ver}")
    try:
        import jax
        print(f"backend  {jax.default_backend()} "
              f"({len(jax.devices())} device(s))")
        if _probe("jax") and not hasattr(jax, "shard_map"):
            print("note     jax.shard_map absent -> "
                  "repro.distributed.compat fallback in use")
    except Exception as e:                                  # noqa: BLE001
        print(f"backend  probe failed: {e}")
    if missing_required:
        print(f"FATAL: missing required deps: {missing_required}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
