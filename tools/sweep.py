#!/usr/bin/env python3
"""Parallel dry-run sweep driver: one subprocess per (arch × shape × mesh)
cell (each sets XLA_FLAGS before jax import), N workers, JSON per cell.

  python tools/sweep.py --out results/dryrun --workers 6
  python tools/sweep.py --multi-pod --out results/dryrun
"""
import argparse
import json
import os
import subprocess
import sys
from concurrent.futures import ThreadPoolExecutor, as_completed

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARCHS = ["mixtral-8x7b", "qwen3-moe-235b-a22b", "whisper-base",
         "internvl2-26b", "zamba2-1.2b", "qwen2.5-32b", "codeqwen1.5-7b",
         "tinyllama-1.1b", "llama3-405b", "xlstm-125m"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_one(arch, shape, args):
    pod = "2pod" if args.multi_pod else "1pod"
    name = f"{arch}__{shape}__{pod}__{args.qcfg}"
    path = os.path.join(args.out, name + ".json")
    if os.path.exists(path) and not args.force:
        with open(path) as f:
            return name, json.load(f).get("status"), "cached"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--qcfg", args.qcfg, "--act-mode",
           args.act_mode, "--out", args.out]
    if args.multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    try:
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=args.timeout, env=env, cwd=ROOT)
        ok = "ok" if r.returncode == 0 else "error"
        tail = (r.stdout + r.stderr).strip().splitlines()
        return name, ok, tail[-1][:200] if tail else ""
    except subprocess.TimeoutExpired:
        return name, "timeout", f">{args.timeout}s"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--qcfg", default="nvfp4")
    ap.add_argument("--act-mode", default="sp")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cells = [(a, s) for a in (
        [args.arch] if args.arch else ARCHS) for s in (
        [args.shape] if args.shape else SHAPES)]
    failures = 0
    with ThreadPoolExecutor(max_workers=args.workers) as ex:
        futs = {ex.submit(run_one, a, s, args): (a, s) for a, s in cells}
        for fut in as_completed(futs):
            name, status, msg = fut.result()
            print(f"{status:8s} {name}  {msg}", flush=True)
            failures += status not in ("ok", "cached")
    print(f"done; {failures} failures / {len(cells)} cells")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
