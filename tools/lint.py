#!/usr/bin/env python
"""fp4lint CLI: run the repo's AST invariant rules and diff the baseline.

Usage:
    python tools/lint.py                    # scan src/ tools/ benchmarks/ tests/
    python tools/lint.py src/repro/serve    # scan a subset
    python tools/lint.py --update-baseline  # rewrite tools/lint_baseline.txt
    python tools/lint.py --stats            # print counters after findings

Exit status is non-zero when any finding is not in the baseline OR any
baseline entry is stale (no longer matched by a finding) — both
directions of drift fail, so the checked-in baseline is always exact.

Jax-free: imports only ``repro.analysis`` (pure stdlib), so this runs
before the environment is otherwise usable (tier-1 preflight via
``tools/check_env.py --lint``).
"""
from __future__ import annotations

import argparse
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "src"))

from repro.analysis import (DEFAULT_SCAN_DIRS, all_rule_names,  # noqa: E402
                            baseline_diff, lint_paths, load_baseline,
                            write_baseline)

DEFAULT_BASELINE = os.path.join("tools", "lint_baseline.txt")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to scan (default: "
                         f"{' '.join(DEFAULT_SCAN_DIRS)})")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file, repo-relative (default: "
                         "tools/lint_baseline.txt)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(deterministic sort) instead of failing")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report every finding")
    ap.add_argument("--stats", action="store_true",
                    help="print per-rule counters and runtime")
    args = ap.parse_args(argv)

    findings, stats = lint_paths(args.paths or None, root=REPO_ROOT)

    baseline_path = os.path.join(REPO_ROOT, args.baseline)
    if args.update_baseline:
        write_baseline(baseline_path, findings)
        print(f"wrote {len(findings)} baseline entr"
              f"{'y' if len(findings) == 1 else 'ies'} -> {args.baseline}")
        return 0

    if args.no_baseline:
        new, stale = list(findings), []
    else:
        # a partial scan can't see the whole baseline: only judge entries
        # for files we actually scanned, and never report staleness for
        # the rest
        baseline = load_baseline(baseline_path)
        if args.paths:
            scanned = {f.path for f in findings}
            prefixes = tuple(p.rstrip("/") + "/" for p in args.paths)
            baseline = [b for b in baseline
                        if b.split(":", 1)[0] in scanned
                        or b.startswith(prefixes)
                        or any(b.split(":", 1)[0] == p.rstrip("/")
                               for p in args.paths)]
        new, stale = baseline_diff(findings, baseline)

    for f in new:
        print(f.render())
    for key in stale:
        print(f"stale baseline entry (violation fixed? delete it): {key}")

    if args.stats or new or stale:
        per_rule = ", ".join(f"{k}={v}" for k, v in
                             sorted(stats.per_rule.items())) or "none"
        print(f"fp4lint: {stats.files_scanned} files, "
              f"{stats.findings} finding(s) ({per_rule}), "
              f"{stats.suppressed} pragma-suppressed, "
              f"{len(new)} new, {len(stale)} stale, "
              f"{stats.runtime_s * 1e3:.0f} ms "
              f"[rules: {', '.join(all_rule_names())}]")
    if new or stale:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
