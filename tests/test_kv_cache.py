"""Block-quantized KV cache (PR 3): round-trip bounds, fused-read
equivalence, GQA + SWA rolling buffers, engine-level bounded divergence.

Layers of evidence:
  * kv_quant_rows/kv_dequant round-trip error is bounded per block (the
    E2M1 / E4M3 grids' worst-case relative spacing);
  * the fused decode read (models/layers._attn_decode_packed) and the
    Pallas kernel (kernels/flash_attn.flash_attention_packed, interpret)
    both match the dequantize-then-dense-softmax oracle bit-tight —
    including GQA, sliding windows and rolling (wrapped) buffers;
  * prefill+decode through the registry with a packed cache stays close
    to the bf16-cache path (the quantization is a bounded perturbation);
  * the Engine's packed cache is ~3.56x smaller than bf16 and packed
    weights remain token-identical to fake-quant under it.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import fqt
from repro.core.quantize import (KV_CACHE_FORMATS, kv_bytes_per_elem,
                                 kv_dequant, kv_quant_rows)
from repro.kernels import ref
from repro.kernels.flash_attn import flash_attention_packed
from repro.models import registry
from repro.models.layers import (KVCache, PackedKVCache, _attn_decode_packed,
                                 attention_core, make_kv_cache)
from repro.serve import Engine, ServeConfig

FMTS = ("nvfp4", "fp8")


def _rand(shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape)
                       .astype(np.float32)).astype(dtype)


# ---- round-trip error bounds ---------------------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
def test_kv_roundtrip_error_bounds(fmt):
    """Per-element error <= half the worst grid spacing times the block
    scale: E2M1's widest step is 2 at scale absmax/6, E4M3's relative
    step is 2^-3."""
    x = _rand((4, 7, 3, 64), seed=1)
    codes, scales = kv_quant_rows(x, fmt)
    xd = kv_dequant(codes, scales, fmt, dtype=jnp.float32)
    xb = np.asarray(x).reshape(4, 7, 3, 4, 16)
    eb = np.abs(np.asarray(xd).reshape(xb.shape) - xb)
    absmax = np.abs(xb).max(-1, keepdims=True)
    # rtn half-step + scale-quantization headroom
    bound = absmax * ((1 / 6) + 0.08 if fmt == "nvfp4" else 0.075)
    assert (eb <= bound + 1e-7).all(), (eb / absmax).max()


@pytest.mark.parametrize("fmt", FMTS)
def test_kv_roundtrip_zero_and_dtype(fmt):
    z = jnp.zeros((2, 3, 1, 32), jnp.bfloat16)
    codes, scales = kv_quant_rows(z, fmt)
    np.testing.assert_array_equal(
        np.asarray(kv_dequant(codes, scales, fmt), np.float32), 0.0)
    x = _rand((2, 3, 1, 32), seed=2, dtype=jnp.bfloat16)
    xd = kv_dequant(*kv_quant_rows(x, fmt), fmt)
    assert xd.dtype == jnp.bfloat16


def test_kv_bytes_per_elem_table():
    assert kv_bytes_per_elem("bf16") == 2.0
    assert kv_bytes_per_elem("nvfp4") == 0.5625
    assert kv_bytes_per_elem("fp8") == 1.125
    assert 2.0 / kv_bytes_per_elem("nvfp4") > 3.0
    with pytest.raises(ValueError):
        kv_bytes_per_elem("int3")


def test_kv_quant_rejects_unknown_format():
    with pytest.raises(ValueError, match="format"):
        kv_quant_rows(jnp.zeros((2, 32)), "bf16")


# ---- cache container -----------------------------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
def test_packed_cache_shapes_and_bytes(fmt):
    c = PackedKVCache.init(2, 64, 4, 32, fmt=fmt)
    bf = KVCache.init(2, 64, 4, 32)
    bf_bytes = int(bf.k.size * 2 + bf.v.size * 2)
    ratio = bf_bytes / c.nbytes()
    expect = 2.0 / kv_bytes_per_elem(fmt)
    assert abs(ratio - expect) < 1e-6, ratio
    if fmt == "nvfp4":
        assert ratio > 3.0          # the acceptance-criteria floor


def test_packed_cache_rejects_bad_head_dim():
    with pytest.raises(ValueError, match="head_dim"):
        PackedKVCache.init(1, 8, 2, 24, fmt="nvfp4")   # 24 % 16 != 0


def test_make_kv_cache_dispatch():
    assert isinstance(make_kv_cache(1, 8, 2, 32, kv_format="bf16"), KVCache)
    for fmt in FMTS:
        c = make_kv_cache(1, 8, 2, 32, kv_format=fmt)
        assert isinstance(c, PackedKVCache) and c.fmt == fmt
    assert set(FMTS) | {"bf16"} == set(KV_CACHE_FORMATS)


# ---- fused decode read == dequantize-then-attend oracle ------------------------


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("window", [None, 24])
def test_decode_read_matches_oracle(fmt, window):
    """GQA decode over a linear cache: the chunked dequant-fused scan must
    equal full dequantization + dense softmax bit-tight (f32)."""
    B, S, H, KVH, D = 2, 64, 4, 2, 32
    q = _rand((B, 1, H, D), seed=3)
    k = _rand((B, S, KVH, D), seed=4)
    v = _rand((B, S, KVH, D), seed=5)
    kc, ks = kv_quant_rows(k, fmt)
    vc, vs = kv_quant_rows(v, fmt)
    cache = PackedKVCache(kc, ks, vc, vs, jnp.asarray(48, jnp.int32), fmt, 16)
    qpos = jnp.asarray([47], jnp.int32)
    kpos = jnp.arange(S, dtype=jnp.int32)
    out = _attn_decode_packed(q, cache, qpos=qpos, kpos=kpos, causal=True,
                              window=window, kv_len=jnp.asarray(48),
                              chunk=16)
    want = ref.packed_attention_ref(q, kc, ks, vc, vs, fmt=fmt, causal=True,
                                    window=window, kv_len=48, q_offset=47)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("fmt", FMTS)
def test_rolling_swa_buffer_packed_vs_bf16(fmt):
    """SWA rolling buffer past the wrap point: write tokens one at a time
    through attn_apply's slot/mask logic with BOTH cache types; the packed
    path must equal attention over the *dequantized* packed buffer (exact
    oracle) and stay close to the bf16 cache (bounded perturbation)."""
    window = buf = 16
    B, KVH, D, T = 1, 2, 32, 24                      # T > buf: wraps
    H = KVH
    ks = _rand((T, B, 1, KVH, D), seed=6)
    vs = _rand((T, B, 1, KVH, D), seed=7)
    qs = _rand((T, B, 1, H, D), seed=8)

    pc = PackedKVCache.init(B, buf, KVH, D, fmt=fmt)
    bc = KVCache.init(B, buf, KVH, D, jnp.float32)
    for t in range(T):
        idx = jnp.asarray([t % buf])
        kcod, ksc = kv_quant_rows(ks[t], fmt)
        vcod, vsc = kv_quant_rows(vs[t], fmt)
        pc = PackedKVCache(pc.k_codes.at[:, idx].set(kcod),
                           pc.k_scales.at[:, idx].set(ksc),
                           pc.v_codes.at[:, idx].set(vcod),
                           pc.v_scales.at[:, idx].set(vsc),
                           jnp.asarray(t + 1), fmt, 16)
        bc = KVCache(bc.k.at[:, idx].set(ks[t]), bc.v.at[:, idx].set(vs[t]),
                     jnp.asarray(t + 1))
    # decode read at position T-1: slot j holds the latest token with
    # pos % buf == j (models/layers.attn_apply's SWA kpos rule)
    last = T - 1
    slot = jnp.arange(buf, dtype=jnp.int32)
    kpos = last - ((last % buf - slot) % buf)
    qpos = jnp.asarray([last], jnp.int32)
    kv_len = jnp.asarray(min(T, buf))
    out_p = _attn_decode_packed(qs[-1], pc, qpos=qpos, kpos=kpos,
                                causal=True, window=window, kv_len=kv_len,
                                chunk=8)
    dk, dv = pc.dequant(jnp.float32)
    want = attention_core(qs[-1], dk, dv, qpos=qpos, kpos=kpos, causal=True,
                          window=window, chunk=2 ** 30, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    out_b = attention_core(qs[-1], bc.k, bc.v, qpos=qpos, kpos=kpos,
                           causal=True, window=window, chunk=2 ** 30,
                           kv_len=kv_len)
    err = np.abs(np.asarray(out_p) - np.asarray(out_b))
    scale = np.abs(np.asarray(out_b)).max()
    assert err.max() < 0.35 * scale, (err.max(), scale)


# ---- Pallas kernel (interpret mode) -------------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_flash_packed_kernel_matches_oracle(fmt, causal, window):
    B, S, H, KVH, D = 2, 64, 4, 2, 32
    q = _rand((B, S, H, D), seed=9)
    k = _rand((B, S, KVH, D), seed=10)
    v = _rand((B, S, KVH, D), seed=11)
    kc, ks = kv_quant_rows(k, fmt)
    vc, vs = kv_quant_rows(v, fmt)
    out = flash_attention_packed(q, kc, ks, vc, vs, fmt=fmt, causal=causal,
                                 window=window, block_q=32, block_kv=32,
                                 interpret=True)
    want = ref.packed_attention_ref(q, kc, ks, vc, vs, fmt=fmt,
                                    causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_packed_kernel_decode_offset():
    """Sq=1 decode-style read with q_offset and a short valid kv_len."""
    B, S, H, KVH, D = 2, 64, 4, 2, 32
    q = _rand((B, 1, H, D), seed=12)
    k = _rand((B, S, KVH, D), seed=13)
    v = _rand((B, S, KVH, D), seed=14)
    kc, ks = kv_quant_rows(k, "nvfp4")
    vc, vs = kv_quant_rows(v, "nvfp4")
    out = flash_attention_packed(q, kc, ks, vc, vs, fmt="nvfp4", causal=True,
                                 q_offset=S - 1, kv_len=48, block_q=32,
                                 block_kv=32, interpret=True)
    want = ref.packed_attention_ref(q, kc, ks, vc, vs, fmt="nvfp4",
                                    causal=True, q_offset=S - 1, kv_len=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_packed_kernel_rejects_bad_layout():
    q = _rand((1, 32, 2, 32), seed=0)
    k = _rand((1, 32, 2, 32), seed=1)
    kc, ks = kv_quant_rows(k, "nvfp4")
    with pytest.raises(ValueError, match="format"):
        flash_attention_packed(q, kc, ks, kc, ks, fmt="int4", interpret=True)
    with pytest.raises(ValueError, match="layout"):
        flash_attention_packed(q, kc[..., :8], ks, kc[..., :8], ks,
                               fmt="nvfp4", interpret=True)


# ---- model-level: registry prefill/decode with a packed cache ------------------


@pytest.fixture(scope="module")
def tiny():
    return get_config("llama2-60m").smoke()


@pytest.mark.parametrize("fmt", FMTS)
def test_gqa_decode_bounded_divergence(tiny, fmt):
    """GQA (2 groups) prefill+decode: packed-cache logits are a bounded
    perturbation of the bf16-cache logits."""
    cfg = dataclasses.replace(tiny, n_kv_heads=2)       # 4 heads -> G=2
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    qcfg = fqt.qaf_config()
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 12)), jnp.int32)
    logits = {}
    for f in ("bf16", fmt):
        carry = registry.make_decode_state(cfg, 2, 32, kv_cache_format=f)
        _, carry = registry.prefill(params, cfg, qcfg, toks, carry, seed=0)
        lg, carry = registry.decode_step(params, cfg, qcfg, toks[:, -1:],
                                         carry, seed=0)
        lg2, _ = registry.decode_step(params, cfg, qcfg, toks[:, -1:],
                                      carry, seed=0)
        logits[f] = np.asarray(lg2, np.float32)
        assert np.isfinite(logits[f]).all()
    ref_l = logits["bf16"]
    rel = (np.sqrt(np.mean((logits[fmt] - ref_l) ** 2))
           / np.sqrt(np.mean(ref_l ** 2)))
    assert rel < 0.6, rel        # random-init worst case; trained ~ O(%)


def test_swa_model_decode_past_wrap():
    """Mixtral smoke (SWA window=64): decode past the rolling-buffer wrap
    with a packed cache stays finite and bounded vs bf16."""
    cfg = get_config("mixtral_8x7b").smoke()
    assert cfg.sliding_window is not None
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    qcfg = fqt.qaf_config()
    toks = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (1, 60)), jnp.int32)
    out = {}
    forced = None
    for f in ("bf16", "nvfp4"):
        carry = registry.make_decode_state(cfg, 1, 128, kv_cache_format=f)
        _, carry = registry.prefill(params, cfg, qcfg, toks, carry, seed=0)
        tok, stream = toks[:, -1:], []
        for t in range(8):                      # 60 + 8 > window=64: wraps
            lg, carry = registry.decode_step(params, cfg, qcfg, tok, carry,
                                             seed=0)
            # teacher-force the bf16 stream so both runs see the same
            # token history and the final logits are comparable
            tok = (jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
                   if forced is None else forced[t])
            stream.append(tok)
        if forced is None:
            forced = stream
        out[f] = np.asarray(lg, np.float32)
        assert np.isfinite(out[f]).all()
    rel = (np.sqrt(np.mean((out["nvfp4"] - out["bf16"]) ** 2))
           / np.sqrt(np.mean(out["bf16"] ** 2)))
    assert rel < 0.8, rel


# ---- engine-level --------------------------------------------------------------


def test_engine_packed_cache_default_and_escape_hatch(tiny):
    params = registry.init_params(tiny, jax.random.PRNGKey(0))
    assert ServeConfig().kv_cache_format == "nvfp4"
    prompts = [np.random.default_rng(0).integers(0, tiny.vocab_size, 8)]
    for fmt in ("bf16", "nvfp4", "fp8"):
        eng = Engine(tiny, params,
                     ServeConfig(batch_size=1, max_len=48,
                                 kv_cache_format=fmt))
        out = eng.generate(prompts, max_new=4)
        assert out[0].dtype == np.int32 and 1 <= len(out[0]) <= 4


def test_engine_tokens_identical_packed_weights_under_packed_cache(tiny):
    """Weight packing stays bit-identical with a quantized KV cache: both
    engines quantize the cache the same way, so tokens must match."""
    params = registry.init_params(tiny, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_size=2, max_len=64, kv_cache_format="nvfp4")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, tiny.vocab_size, 8),
               rng.integers(0, tiny.vocab_size, 5)]
    out_p = Engine(tiny, params, scfg).generate(prompts, max_new=6)
    out_f = Engine(tiny, params, scfg,
                   pack_weights=False).generate(prompts, max_new=6)
    for a, b in zip(out_p, out_f):
        np.testing.assert_array_equal(a, b)


def test_teacher_forced_token_agreement(tiny):
    """Bounded divergence on the smoke config: with the bf16 run's tokens
    forced into the packed-cache run, per-step greedy picks agree on a
    solid fraction of steps even at random init (near-tied logit rows are
    the flips; trained models agree far more)."""
    params = registry.init_params(tiny, jax.random.PRNGKey(0))
    qcfg = fqt.qaf_config()
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, tiny.vocab_size, (2, 12)), jnp.int32)
    steps = 12

    def run(fmt, forced):
        carry = registry.make_decode_state(tiny, 2, 64, kv_cache_format=fmt)
        last, carry = registry.prefill(params, tiny, qcfg, toks, carry)
        tok = jnp.argmax(last, -1).astype(jnp.int32)[:, None]
        picks = []
        for t in range(steps):
            lg, carry = registry.decode_step(params, tiny, qcfg, tok, carry)
            pick = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
            picks.append(np.asarray(pick))
            tok = (pick[:, None] if forced is None
                   else forced[t][:, None])
        return np.stack(picks)

    ref_picks = run("bf16", None)
    forced = [jnp.asarray(p) for p in ref_picks]
    agree = float(np.mean(run("nvfp4", forced) == ref_picks))
    assert agree >= 0.4, agree
