"""Shared fixtures: the multi-device subprocess runner.

jax pins the device count at first backend use, so multi-device tests
(forced host CPU devices) cannot run in the main pytest process — it keeps
its single real device.  ``run_multidev`` runs a snippet in a SUBPROCESS
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set both in
the environment and (belt-and-braces) at the top of the generated script,
before jax can initialize.  Heavy TP sweeps built on it are marked
``slow`` (pytest.ini) so tier-1 stays fast.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
MULTIDEV_DEVICES = 4


@pytest.fixture
def run_multidev(tmp_path):
    """Run a python snippet under forced host devices; asserts exit 0.

    The XLA flag is injected BEFORE the snippet so a script can never
    import jax first by accident; PYTHONPATH points at ``src``.  Returns
    the CompletedProcess (stdout carries the snippet's own markers).
    """

    def run(body: str, devices: int = MULTIDEV_DEVICES, timeout: int = 900):
        flag = f"--xla_force_host_platform_device_count={devices}"
        script = tmp_path / "multidev.py"
        script.write_text(
            f'import os\nos.environ["XLA_FLAGS"] = "{flag}"\n'
            + textwrap.dedent(body))
        env = dict(os.environ)
        env["PYTHONPATH"] = _SRC
        env["XLA_FLAGS"] = flag
        r = subprocess.run([sys.executable, str(script)],
                           capture_output=True, text=True, timeout=timeout,
                           env=env)
        assert r.returncode == 0, r.stdout + r.stderr
        return r

    return run
