"""Checkpoint tests: atomicity, GC, idempotent re-save, and ELASTIC
resharding (save under one mesh, restore under a different topology)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree(key=0):
    kw, km = jax.random.split(jax.random.PRNGKey(key))
    return {
        "w": jax.random.normal(kw, (16, 32), jnp.float32),
        "b": jnp.zeros((32,), jnp.bfloat16),
        "step": jnp.asarray(7, jnp.int32),
        "nested": {"m": jax.random.normal(km, (4, 8), jnp.float32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t)
    assert ckpt.latest_step(str(tmp_path)) == 5
    restored = ckpt.restore(str(tmp_path), 5, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_pointer_and_gc(tmp_path):
    t = _tree()
    for step in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), step, t, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3


def test_idempotent_resave(tmp_path):
    t = _tree()
    p1 = ckpt.save(str(tmp_path), 9, t)
    p2 = ckpt.save(str(tmp_path), 9, t)     # trainer end-of-run re-save
    assert p1 == p2
    assert ckpt.latest_step(str(tmp_path)) == 9


def test_structure_mismatch_raises(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    with pytest.raises(ValueError, match="leaves"):
        ckpt.restore(str(tmp_path), 1, {"only": t["w"]})


_ELASTIC = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import ckpt

    d = sys.argv[1]
    t = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 32),
                                jnp.float32)}

    # save under a (4, 2) mesh with w sharded (data, model)
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    w_a = jax.device_put(t["w"], NamedSharding(mesh_a, P("data", "model")))
    ckpt.save(d, 3, {"w": w_a})

    # restore under a DIFFERENT topology: (2, 4), model-major sharding
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    sh_b = {"w": NamedSharding(mesh_b, P("model", "data"))}
    _, restored = ckpt.restore_latest(d, t, shardings=sh_b)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))
    assert restored["w"].sharding.mesh.devices.shape == (2, 4)
    print("elastic OK")
""")


@pytest.mark.slow
def test_elastic_resharding_across_meshes(tmp_path):
    """A checkpoint written on a 4×2 mesh restores onto a 2×4 mesh with a
    different PartitionSpec — the elastic-restart path."""
    script = tmp_path / "elastic.py"
    script.write_text(_ELASTIC)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run(
        [sys.executable, str(script), str(tmp_path / "ck")],
        capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "elastic OK" in r.stdout
