"""Observability (PR 10): the tracer changes NOTHING but what you can see.

Layers of evidence:
  * EXACTNESS: with a live tracer attached, the continuous engine's
    token streams are BIT-identical to an untraced run across every KV
    format (nvfp4/fp8/bf16), with speculative decoding + chunked
    prefill + the prefix cache all composed — and the five-program jit
    caches stay at one entry each (tracing is host-side only; fp4lint's
    obs-in-jit rule enforces that statically);
  * span balance: every request span opened at submit is closed by
    done/cancel — abort/timeout at EVERY lifecycle stage included —
    and preemption keeps the span open (the resumed request is the
    same request);
  * counter conservation: the tracer's page counters reconcile with
    the page pool at drain, and its sched_* counters agree with the
    scheduler's own stats dict;
  * the exporter: round-trips valid Chrome trace-event JSON (required
    keys, known phases, numeric timestamps, metadata-first ordering);
  * train telemetry: the trainer's √3-floor series lands exactly one
    entry per logged step, with per-layer ratio gauges for every
    parameter leaf and rounding/scale-health tallies alongside.
"""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp import given, settings, strategies as st

from repro.obs import (NULL_TRACER, Counters, Tracer, load_trace,
                       validate_events)
from repro.serve.metrics import MetricsRecorder
from repro.serve.scheduler import Request, Scheduler

FMTS = ("nvfp4", "fp8", "bf16")
NO_EOS = -1

_STATE = {}


def _tiny():
    if "cfg" not in _STATE:
        import jax
        from repro.configs import get_config
        from repro.models import registry
        _STATE["cfg"] = get_config("llama2-60m").smoke()
        _STATE["params"] = registry.init_params(_STATE["cfg"],
                                                jax.random.PRNGKey(0))
    return _STATE["cfg"], _STATE["params"]


# ---- tracer core (jax-free) ---------------------------------------------------


def test_tracer_simulated_clock_and_span_accounting():
    trc = Tracer(clock="tick", process="t")
    trc.set_time(5)
    trc.begin("req:0", "request", plen=7)
    trc.instant("req:0", "admit")
    trc.counter("pages", 3)
    trc.gauge("depth", 2)
    trc.set_time(9)
    trc.end("req:0", "request")
    evs = [e for e in trc.trace_events() if e["ph"] != "M"]
    assert [e["ts"] for e in evs] == [5, 5, 5, 5, 9]
    assert trc.spans_opened == 1 and trc.spans_closed == 1
    assert trc.open_spans() == {}
    assert trc.counters["pages"] == 3 and trc.gauges["depth"] == 2


def test_span_context_manager_balances_on_error():
    trc = Tracer()
    with pytest.raises(RuntimeError):
        with trc.span("t", "work"):
            raise RuntimeError("boom")
    assert trc.open_spans() == {}


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    NULL_TRACER.set_time(3)
    NULL_TRACER.begin("t", "x")
    NULL_TRACER.gauge("g", 1.0)
    with NULL_TRACER.span("t", "y"):
        pass
    assert NULL_TRACER.counter("n", 5) == 0
    assert NULL_TRACER.n_events == 0 and NULL_TRACER.trace_events() == []
    with pytest.raises(RuntimeError):
        NULL_TRACER.export("/dev/null")
    # untraced host objects hold the shared singleton, not None
    assert Scheduler(n_slots=1, max_len=16, page_size=4).tracer \
        is NULL_TRACER
    assert MetricsRecorder().tracer is NULL_TRACER


def test_counters_substrate_mapping_protocol():
    c = Counters({"a": 1})
    c.inc("a", 2)
    c.inc("b")
    c.set("a", 5)
    assert dict(c) == {"a": 5, "b": 1}
    assert c["a"] == 5 and c.get("zzz") == 0 and "b" in c and len(c) == 2
    assert sorted(c.keys()) == ["a", "b"]
    c.clear()
    assert dict(c) == {}


def test_metrics_recorder_on_counter_substrate():
    rec = MetricsRecorder(tracer=Tracer())
    rec.submitted(0, arrival=0, deadline=None)
    rec.admitted(0, 1)
    rec.first_token(0, 2)
    rec.finished(0, 4, ntokens=3)
    assert dict(rec.lifecycle) == {"submitted": 1, "admitted": 1,
                                   "first_tokens": 1, "finished": 1}
    rec.set_counters({"admitted": 1, "completed": 1})
    assert isinstance(rec.counters, Counters)
    assert dict(rec.counters) == {"admitted": 1, "completed": 1}
    # percentile semantics survive the rebase: summary shape unchanged
    s = rec.summary()
    assert s["ttft_ticks"]["p50"] == 2 and s["completed"] == 1
    assert s["counters"] == {"admitted": 1, "completed": 1}
    # and the tracer saw the lifecycle as events
    names = {e["name"] for e in rec.tracer.trace_events()}
    assert {"met_submitted", "met_finished", "first_token"} <= names


# ---- lifecycle sweep: span balance at every abort stage (jax-free) ------------


@settings(max_examples=8, deadline=None)
@given(abort_tick=st.integers(min_value=0, max_value=6))
def test_lifecycle_span_balance_at_any_stage(abort_tick):
    """A victim aborted at every possible tick of its life — queued,
    mid-chunked-prefill, decoding, or already finished: every request
    span still closes exactly once, the tracer's sched_* counters agree
    with the scheduler's stats, and the page counters conserve."""
    trc = Tracer(clock="tick")
    sched = Scheduler(n_slots=2, max_len=32, page_size=4, prefill_chunk=3,
                      tracer=trc)
    sched.submit(Request(0, np.arange(10, dtype=np.int32), max_new=4))
    sched.submit(Request(1, np.arange(9, dtype=np.int32), max_new=4,
                         abort_at=abort_tick))
    sched.submit(Request(2, np.arange(8, dtype=np.int32), max_new=3,
                         arrival=1))
    for tick in range(30):
        sched.expire(tick)
        sched.admit(tick)
        sched.prefill_work(tick)
        T = sched.tick_steps(2)
        sched.ensure_capacity(T)
        if T:
            for slot in sched.decoding_slots():
                sched.commit(slot, np.full((T,), 7, np.int32), NO_EOS)
        if not sched.has_work():
            break
    assert not sched.has_work()
    assert trc.spans_opened == 3            # one span per submitted request
    assert trc.spans_closed == 3
    assert trc.open_spans() == {}
    c = trc.counters
    assert c.get("sched_admitted") == sched.stats["admitted"]
    assert c.get("sched_completed") == sched.stats["completed"]
    assert c.get("sched_cancelled") == sched.stats["cancelled"]
    assert c.get("sched_completed") + c.get("sched_cancelled") == 3
    alloc = (c.get("pages_private") + c.get("pages_shared")
             + c.get("pages_demand"))
    assert alloc == c.get("pages_released")
    assert sched.pool.pages_in_use == 0
    # events are schema-valid without an export round-trip
    assert validate_events(trc.trace_events()) == []


def test_preemption_keeps_request_span_open():
    trc = Tracer(clock="tick")
    sched = Scheduler(n_slots=1, max_len=32, page_size=4,
                      prefix_cache=True, tracer=trc)
    sched.submit(Request(7, np.arange(8, dtype=np.int32), max_new=12))
    sched.admit(0)
    sched.commit(0, np.asarray([9], np.int32), NO_EOS)
    sched._preempt(0)
    assert trc.open_spans() == {("req:7", "request"): 1}
    assert trc.counters.get("sched_preempted") == 1
    # resume and finish: the SAME span closes (no second begin)
    sched.admit(1)
    while sched.has_work():
        T = sched.tick_steps(4)
        sched.ensure_capacity(T)
        for slot in list(sched.decoding_slots()):
            sched.commit(slot, np.full((max(T, 1),), 9, np.int32), NO_EOS)
    assert trc.spans_opened == 1 and trc.open_spans() == {}


# ---- exporter round-trip ------------------------------------------------------


def test_export_round_trip_chrome_schema(tmp_path):
    trc = Tracer(clock="tick", process="unit")
    trc.set_time(1)
    trc.begin("req:0", "request")
    trc.counter("pages", 2)
    trc.instant("req:0", "admit", slot=0)
    trc.gauge("depth", 3.5)
    trc.end("req:0", "request")
    path = str(tmp_path / "trace.json")
    assert trc.export(path) == path
    with open(path) as f:
        doc = json.load(f)
    assert set(doc) == {"traceEvents", "displayTimeUnit", "otherData"}
    assert doc["otherData"] == {"clock": "tick", "process": "unit"}
    events = load_trace(path)
    assert validate_events(events) == []
    assert len(events) == len(trc.trace_events())
    phases = [e["ph"] for e in events]
    assert phases.count("B") == 1 and phases.count("E") == 1
    assert phases.count("C") == 2 and phases.count("i") == 1
    # metadata first: process_name, then thread_name per track
    assert events[0]["name"] == "process_name"
    inst = next(e for e in events if e["ph"] == "i")
    assert inst["s"] == "t" and inst["args"]["slot"] == 0
    # the bare-array form loads too
    bare = str(tmp_path / "bare.json")
    with open(bare, "w") as f:
        json.dump(trc.trace_events(), f)
    assert load_trace(bare) == events


def test_validate_events_flags_bad_events():
    assert validate_events([{"name": "x", "ph": "B", "ts": 0, "pid": 1,
                             "tid": 1}]) == []
    probs = validate_events([
        {"ph": "B", "ts": 0, "pid": 1, "tid": 1},          # missing name
        {"name": "x", "ph": "Z", "ts": 0, "pid": 1, "tid": 1},
        {"name": "x", "ph": "B", "ts": "later", "pid": 1, "tid": 1},
        "not an event"])
    assert len(probs) == 4


# ---- the engine: tracer on == tracer off, bit for bit -------------------------


_BASELINE = {}


def _requests(cfg, max_new=10):
    rng = np.random.default_rng(7)
    return [Request(i, rng.integers(0, cfg.vocab_size, n), max_new=max_new)
            for i, n in enumerate((33, 12, 37))]


@pytest.mark.parametrize("fmt", FMTS)
def test_tracer_on_off_bit_identical_full_compose(fmt):
    """Speculative decoding + chunked prefill + prefix cache, with and
    without a tracer: identical tokens, identical jit-cache guards."""
    from repro.serve import ContinuousEngine, ServeConfig
    cfg, params = _tiny()

    def scfg():
        return ServeConfig(batch_size=2, max_len=96, eos_id=NO_EOS,
                           kv_cache_format=fmt, page_size=16,
                           spec_k=3, draft_layers=1, prefill_chunk=5,
                           prefix_cache=True)

    if fmt not in _BASELINE:
        _BASELINE[fmt] = ContinuousEngine(cfg, params,
                                          scfg()).run(_requests(cfg))
    want = _BASELINE[fmt]
    trc = Tracer(clock="tick")
    eng = ContinuousEngine(cfg, params, scfg(), tracer=trc)
    res = eng.run(_requests(cfg))
    for rid in sorted(want):
        np.testing.assert_array_equal(res[rid], want[rid])
    # the five-program contract holds with the tracer attached
    assert eng.verify_compiles == 1
    assert eng.chunk_compiles == 1
    assert eng.prefill_suffix_compiles == 1
    assert eng.prefill_compiles == 0 and eng.decode_compiles == 0
    # and the trace itself is balanced and schema-valid
    assert trc.spans_opened == trc.spans_closed
    assert trc.open_spans() == {}
    assert trc.counters.get("sched_completed") == len(want)
    assert trc.counters.get("jit_compiles") == 3
    names = {e["name"] for e in trc.trace_events()}
    assert {"request", "tick", "jit_compile", "first_token"} <= names
    assert validate_events(trc.trace_events()) == []


# ---- train telemetry: one √3-series entry per logged step ---------------------


def test_trainer_sqrt3_series_one_entry_per_logged_step():
    import jax
    from repro.core import fqt
    from repro.train import TrainConfig, Trainer, TrainerConfig
    from repro.data.pipeline import DataConfig
    cfg, _ = _tiny()
    trc = Tracer(clock="step", process="train")
    trainer = Trainer(
        cfg, fqt.nvfp4_paper_config(), TrainConfig(remat=False),
        TrainerConfig(total_steps=6, log_every=2, ckpt_every=100),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4),
        tracer=trc)
    assert trainer.tcfg.layer_stats        # auto-enabled by the live tracer
    state = trainer.run(jax.random.PRNGKey(0))
    logged = [0, 2, 4]                     # steps where step % log_every == 0
    evs = trc.trace_events()
    gnr = [e for e in evs if e["ph"] == "C" and e["name"] == "gnr"]
    assert [e["ts"] for e in gnr] == logged    # exactly one per logged step
    # per-layer ratio gauges: one per parameter leaf per logged step
    n_leaves = len(jax.tree.leaves(state.params))
    ratios = [e for e in evs
              if e["ph"] == "C" and e["name"].startswith("ratio")]
    assert len(ratios) == n_leaves * len(logged)
    # rounding tallies reflect the paper's mixed SR/RtN placement
    c = trc.counters
    assert c.get("rounding_sr_points") > 0
    assert c.get("rounding_rtn_points") > 0
    # scale health probed the forward weight spec each logged step
    assert c.get("scale_blocks") > 0
    assert c.get("scale_saturated") >= 0 and c.get("scale_underflow") >= 0
    assert validate_events(evs) == []


def test_trainer_without_tracer_keeps_layer_stats_off():
    from repro.core import fqt
    from repro.train import TrainConfig, Trainer, TrainerConfig
    from repro.data.pipeline import DataConfig
    cfg, _ = _tiny()
    trainer = Trainer(
        cfg, fqt.nvfp4_paper_config(), TrainConfig(remat=False),
        TrainerConfig(total_steps=1, ckpt_every=100),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
    assert trainer.tracer is NULL_TRACER
    assert not trainer.tcfg.layer_stats
