"""Docs-drift guard (tier-1 fast test): README/docs code snippets must not
drift from the code — import lines import, flags exist, paths resolve.

The check itself lives in tools/check_env.py (``--docs`` mode) so it can
also run standalone in CI / preflight.
"""
import os
import sys

_TOOLS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools")
sys.path.insert(0, _TOOLS)
import check_env  # noqa: E402


def test_docs_pages_exist():
    for rel in check_env.DOC_FILES:
        assert os.path.exists(os.path.join(check_env.REPO_ROOT, rel)), rel


def test_docs_snippets_in_sync(capsys):
    assert check_env.check_docs() == 0, capsys.readouterr().out


def test_docs_check_catches_drift():
    """The guard must actually fail on stale flags/benches/paths/imports."""
    errs = []
    check_env._check_command("python -m repro.launch.serve --no-such-flag",
                             errs, "t")
    check_env._check_command("python -m benchmarks.run --bench nope",
                             errs, "t")
    check_env._check_command("python examples/no_such_example.py", errs, "t")
    check_env._check_import_line("from repro.serve import NotAThing",
                                 errs, "t")
    assert len(errs) == 4, errs


def test_check_env_deps_mode_still_works(capsys):
    assert check_env.main([]) == 0
    assert "python" in capsys.readouterr().out
