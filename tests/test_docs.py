"""Docs-drift guard (tier-1 fast test): README/docs code snippets must not
drift from the code — import lines import, flags exist, paths resolve.

The check itself lives in tools/check_env.py (``--docs`` mode) so it can
also run standalone in CI / preflight.
"""
import os
import sys

_TOOLS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools")
sys.path.insert(0, _TOOLS)
import check_env  # noqa: E402


def test_docs_pages_exist():
    for rel in check_env.DOC_FILES:
        assert os.path.exists(os.path.join(check_env.REPO_ROOT, rel)), rel


def test_docs_snippets_in_sync(capsys):
    assert check_env.check_docs() == 0, capsys.readouterr().out


def test_docs_check_catches_drift():
    """The guard must actually fail on stale flags/benches/paths/imports."""
    errs = []
    check_env._check_command("python -m repro.launch.serve --no-such-flag",
                             errs, "t")
    check_env._check_command("python -m benchmarks.run --bench nope",
                             errs, "t")
    check_env._check_command("python examples/no_such_example.py", errs, "t")
    check_env._check_import_line("from repro.serve import NotAThing",
                                 errs, "t")
    assert len(errs) == 4, errs


def test_check_env_deps_mode_still_works(capsys):
    assert check_env.main([]) == 0
    assert "python" in capsys.readouterr().out


def test_check_env_mesh_mode(capsys):
    """--mesh: jax-free spec-layer self-check (CLI grammar, code/scale
    congruence, drop diagnostics, 4.5 bits/param wire accounting)."""
    assert check_env.main(["--mesh"]) == 0, capsys.readouterr().out
    assert "mesh partition specs" in capsys.readouterr().out


def test_check_env_serve_mode(capsys):
    """--serve: host-side scheduler invariants (refcount conservation,
    radix-tree bookkeeping, no page leaked after a full cycle)."""
    assert check_env.main(["--serve"]) == 0, capsys.readouterr().out
    assert "serving scheduler invariants" in capsys.readouterr().out


def test_check_env_traffic_mode(capsys):
    """--traffic: host-side traffic-harness self-check (workload
    determinism, nearest-rank percentiles, lifecycle conservation,
    per-tick chunk budget)."""
    assert check_env.main(["--traffic"]) == 0, capsys.readouterr().out
    assert "traffic harness" in capsys.readouterr().out


def test_check_env_spec_mode(capsys):
    """--spec: jax-free speculative-decoding self-check (greedy
    acceptance rule, rollback arithmetic, accepted-tokens metrics,
    scheduler spec protocol, partial-suffix resume bookkeeping)."""
    assert check_env.main(["--spec"]) == 0, capsys.readouterr().out
    assert "speculative decoding" in capsys.readouterr().out


def test_check_env_lint_mode(capsys):
    """--lint: the fp4lint AST invariants, baseline-exact (jax-free)."""
    assert check_env.main(["--lint"]) == 0, capsys.readouterr().out
    assert "fp4lint" in capsys.readouterr().out


def test_check_env_obs_mode(capsys):
    """--obs: span balance, counter conservation, tracer no-op contract,
    Chrome trace schema (jax-free)."""
    assert check_env.main(["--obs"]) == 0, capsys.readouterr().out
    assert "observability" in capsys.readouterr().out


def test_check_env_all_mode(capsys):
    """--all: every self-check (docs, serve, traffic, spec, mesh, lint,
    obs, deps) in one go."""
    assert check_env.main(["--all"]) == 0, capsys.readouterr().out
    out = capsys.readouterr().out
    for marker in ("docs snippets", "serving scheduler",
                   "traffic harness", "speculative decoding",
                   "mesh partition specs", "fp4lint", "observability"):
        assert marker in out, (marker, out)


def test_docs_guard_validates_mesh_specs():
    """Quoted ``--mesh`` values must parse with the real CLI grammar, and
    string-literal kwarg VALUES (mesh="tp=2") must not read as kwargs."""
    errs = []
    check_env._check_command("python -m repro.launch.serve --smoke "
                             "--mesh tp=2", errs, "t")
    assert errs == [], errs
    check_env._check_command("python -m repro.launch.serve --smoke "
                             "--mesh ep=3", errs, "t")
    assert len(errs) == 1 and "--mesh" in errs[0]
    errs = []
    check_env._check_guarded_kwargs(
        'sc = ServeConfig(mesh="tp=2", page_size=16)', errs, "t")
    assert errs == [], errs
    check_env._check_guarded_kwargs(
        'sc = ServeConfig(mesh="tp=2", no_such_knob=1)', errs, "t")
    assert len(errs) == 1 and "no_such_knob" in errs[0]


def test_docs_guard_checks_prefix_cache_kwargs():
    """KWARG_GUARDS covers PrefixCache (a plain class — signature-based)
    and still catches a fictitious knob."""
    errs = []
    check_env._check_guarded_kwargs(
        "pc = PrefixCache(pool, page_size=16, max_pages=64)", errs, "t")
    assert errs == [], errs
    check_env._check_guarded_kwargs(
        "pc = PrefixCache(pool, page_size=16, no_such_knob=1)", errs, "t")
    assert len(errs) == 1 and "no_such_knob" in errs[0]
