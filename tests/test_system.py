"""End-to-end system tests: trainer fault tolerance, QAF switching, the
√3 monitor, serving, and train/serve consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import fqt, qaf, threshold
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import registry
from repro.serve import Engine, ServeConfig
from repro.train import (TrainConfig, Trainer, TrainerConfig, init_state,
                         make_train_step)


@pytest.fixture(scope="module")
def tiny():
    return get_config("llama2-60m").smoke()


def _data(cfg, B=4, S=32):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B)


def test_loss_descends_fp4(tiny):
    """Full-FP4 training actually learns (the paper's core claim at smoke
    scale): loss after 30 steps is well below the initial loss."""
    from repro.optim import adamw, schedule
    tcfg = TrainConfig(
        opt=adamw.AdamWConfig(lr_peak=1e-3),
        sched=schedule.ScheduleConfig(peak_lr=1e-3, warmup_steps=5,
                                      total_steps=30),
        remat=False)
    data = SyntheticLM(_data(tiny))
    state = init_state(tiny, tcfg, jax.random.PRNGKey(0))
    fn = make_train_step(tiny, fqt.nvfp4_paper_config(), tcfg)
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, m = fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::6]
    assert np.isfinite(losses).all()


def test_checkpoint_restart_bit_identical(tiny, tmp_path):
    """Kill/restart == uninterrupted run, bit-for-bit (step-indexed data +
    step-seeded SR + atomic checkpoints)."""
    tcfg = TrainConfig(remat=False)
    dc = _data(tiny)

    straight = Trainer(tiny, fqt.nvfp4_paper_config(), tcfg,
                       TrainerConfig(total_steps=12, ckpt_every=100), dc)
    s_a = straight.run(jax.random.PRNGKey(0))

    ck = str(tmp_path / "ck")
    part1 = Trainer(tiny, fqt.nvfp4_paper_config(), tcfg,
                    TrainerConfig(total_steps=6, ckpt_every=6, ckpt_dir=ck),
                    dc)
    part1.run(jax.random.PRNGKey(0))
    part2 = Trainer(tiny, fqt.nvfp4_paper_config(), tcfg,
                    TrainerConfig(total_steps=12, ckpt_every=6, ckpt_dir=ck),
                    dc)
    s_b = part2.run(jax.random.PRNGKey(0))

    assert part2.events[0]["kind"] == "restore"
    for a, b in zip(jax.tree.leaves(s_a.params), jax.tree.leaves(s_b.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_qaf_fixed_step_switch(tiny):
    trainer = Trainer(
        tiny, fqt.nvfp4_paper_config(), TrainConfig(remat=False),
        TrainerConfig(total_steps=8,
                      qaf=qaf.QAFConfig(auto_switch=False,
                                        fixed_switch_step=4)),
        _data(tiny))
    trainer.run(jax.random.PRNGKey(0))
    kinds = [e["kind"] for e in trainer.events]
    assert "qaf_switch" in kinds
    assert trainer.in_qaf


def test_threshold_monitor_math():
    """update() crosses exactly when EMA < √3 after min_steps."""
    cfg = threshold.ThresholdConfig(ema=0.0, min_steps=2)
    st = threshold.init()
    # ratio = gnorm/(sigma*sqrt(d)) = 8/(1*4) = 2 > √3
    st = threshold.update(st, jnp.asarray(8.0), 16, jnp.asarray(1.0), cfg)
    assert not bool(st.crossed)
    # ratio = 4/4 = 1 < √3, step 2 >= min_steps
    st = threshold.update(st, jnp.asarray(4.0), 16, jnp.asarray(1.0), cfg)
    assert bool(st.crossed)


def test_sigma_q_estimate_matches_noise_level():
    """The probe's σ_q matches the actual SR residual std to ~20%."""
    from repro.core.quantize import NVFP4, fake_quant
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 64), jnp.float32)
    spec = NVFP4.with_rounding(stochastic=True)
    q = fake_quant(g, spec, key=jax.random.PRNGKey(1))
    resid = np.std(np.asarray(q - g))
    est = float(threshold.estimate_sigma_q(g, q))
    assert abs(est - resid) / resid < 0.2


def test_engine_generation_shapes(tiny):
    params = registry.init_params(tiny, jax.random.PRNGKey(0))
    eng = Engine(tiny, params, ServeConfig(batch_size=2, max_len=64))
    rng = np.random.default_rng(0)
    out = eng.generate([rng.integers(0, tiny.vocab_size, 8),
                        rng.integers(0, tiny.vocab_size, 5)], max_new=6)
    assert len(out) == 2
    assert all(1 <= len(o) <= 6 for o in out)
    assert all(o.dtype == np.int32 for o in out)


def test_prefill_decode_matches_forward(tiny):
    """Serving path (prefill + decode w/ cache) must reproduce the training
    forward's next-token logits (same FP4-forward numerics)."""
    cfg = dataclasses.replace(tiny, sliding_window=None)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    qcfg = fqt.qaf_config()     # FP4 forward only (deterministic RtN)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)

    logits_full, _ = registry.forward(params, cfg, qcfg, {"tokens": toks},
                                      seed=0, remat=False)
    carry = registry.make_decode_state(cfg, 2, 32)
    last, carry = registry.prefill(params, cfg, qcfg, toks[:, :-1], carry,
                                   seed=0)
    step_logits, _ = registry.decode_step(params, cfg, qcfg, toks[:, -1:],
                                          carry, seed=0)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=0.15, atol=0.3)


def test_straggler_detection(tiny, monkeypatch):
    trainer = Trainer(tiny, fqt.bf16_config(), TrainConfig(remat=False),
                      TrainerConfig(total_steps=10, straggler_factor=2.0),
                      _data(tiny, B=2, S=16))
    real_fn = {}

    def slow_wrap(state, batch):
        import time
        if int(state.step) == 8:
            time.sleep(max(0.5, 3 * np.median(
                [h["dt"] for h in trainer.history])))
        return real_fn["f"](state, batch)

    orig_build = trainer._build_step

    def patched(*a, **k):
        orig_build(*a, **k)
        real_fn["f"] = trainer._step_fn
        trainer._step_fn = slow_wrap

    monkeypatch.setattr(trainer, "_build_step", patched)
    trainer.run(jax.random.PRNGKey(0))
    assert any(e["kind"] == "straggler" for e in trainer.events)
