"""Shared-prefix cache + demand-driven paging (PR 5).

Layers of evidence:
  * page-pool hardening: double-frees and out-of-range ids raise; ref/
    free conserve refcounts; freed-slot page-table rows verifiably point
    at TRASH_PAGE in the engine's carry after a full trace;
  * radix-tree semantics: exact full-page chunk matching, LRU eviction
    over refcount-0 (cache-only) leaves, live pages pinned;
  * demand paging: slots grow across page boundaries mid-decode instead
    of reserving ceil((plen+max_new)/page) up front; pool exhaustion
    preempts the youngest slot deterministically and the requeued request
    regenerates its exact token stream;
  * EXACTNESS: with the prefix cache on, every admission runs the
    quantize-then-attend suffix program (cold: pfx=0), so the suffix
    hidden states are a pure function of the quantized pages — a warm
    admission is BIT-identical to a cold start of the same prompt under
    nvfp4/fp8/bf16 page formats (asserted strictly, no margin gate), and
    skips >= the matched full pages of prefill (tokens-prefilled
    accounting);
  * no recompilation: the suffix program compiles once across warm/cold
    admissions with different (pfx, plen, slot);
  * QAF trainer finale: the packed NVFP4 serving artifact round-trips
    through checkpoint restore into the Engine bit-identically.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import fqt
from repro.checkpoint import ckpt
from repro.models import registry
from repro.models.layers import TRASH_PAGE, PagedKVCache
from repro.serve import (ContinuousEngine, Engine, PagePool, PrefixCache,
                         Request, Scheduler, ServeConfig,
                         pack_model_params)

FMTS = ("nvfp4", "fp8", "bf16")
NO_EOS = -1


# ---- page pool hardening (host-side) -----------------------------------------


def test_pool_double_free_raises():
    pool = PagePool(8)
    a = pool.alloc(2)
    pool.free(a)
    with pytest.raises(ValueError, match="double free"):
        pool.free([a[0]])


def test_pool_out_of_range_and_trash_raise():
    pool = PagePool(8)
    with pytest.raises(ValueError, match="out of range"):
        pool.free([8])
    with pytest.raises(ValueError, match="out of range"):
        pool.free([-1])
    with pytest.raises(ValueError, match="trash"):
        pool.free([TRASH_PAGE])
    with pytest.raises(ValueError, match="not allocated"):
        pool.ref(3)


def test_pool_refcount_conservation():
    pool = PagePool(10)
    a = pool.alloc(4)
    pool.ref(a[1])
    pool.ref(a[1])
    pool.free(a)                      # a[1] still has 2 holders
    assert pool.refcount(a[1]) == 2
    assert pool.free_pages + pool.pages_in_use == 9
    pool.free([a[1], a[1]])
    assert pool.free_pages == 9 and pool.pages_in_use == 0


# ---- radix tree ---------------------------------------------------------------


def test_radix_tree_exact_match_and_insert():
    pool = PagePool(16)
    pc = PrefixCache(pool, page_size=4)
    toks = np.asarray([1, 2, 3, 4, 5, 6, 7, 8, 9])     # 2 full pages + 1
    row = pool.alloc(3)
    assert pc.insert(toks, row) == 2                   # only FULL pages
    assert pc.match(toks) == row[:2]
    assert pc.match(toks[:6]) == row[:1]               # 1 full page
    assert pc.match([1, 2, 3, 5, 5, 6, 7, 8]) == []    # differs in page 0
    # same chunk under a different prefix is a different node
    other = np.asarray([9, 9, 9, 9, 5, 6, 7, 8])
    row2 = pool.alloc(2)
    pc.insert(other, row2)
    assert pc.match(other) == row2
    assert pc.cached_pages == 4


def test_radix_tree_lru_eviction_order():
    pool = PagePool(16)
    pc = PrefixCache(pool, page_size=4)
    a, b = pool.alloc(2), pool.alloc(1)
    pc.insert(np.arange(8), a)          # chain a0 -> a1
    pc.insert(np.arange(100, 104), b)   # single node b
    pool.free(a)
    pool.free(b)                        # cache-only: all evictable
    pc.match(np.arange(100, 104))       # touch b — a is now LRU
    assert pc.evict(1) == 1
    # a1 (the LRU *leaf*) went first; its parent a0 is still matchable
    assert pc.match(np.arange(8)) == a[:1]
    assert pc.evict(2) == 2             # then b (older touch), then a0
    assert pc.cached_pages == 0
    assert pool.free_pages == 15


def test_radix_tree_pins_referenced_pages():
    pool = PagePool(8)
    pc = PrefixCache(pool, page_size=4)
    row = pool.alloc(2)
    pc.insert(np.arange(8), row)        # refcount 2 (slot + cache)
    assert pc.evict(2) == 0             # live slot pins both
    pool.free(row)
    assert pc.evict(2) == 2             # now cache-only -> reclaimable


def test_radix_tree_max_pages_cap():
    pool = PagePool(32)
    pc = PrefixCache(pool, page_size=2, max_pages=3)
    for i in range(5):
        row = pool.alloc(1)
        pc.insert(np.asarray([100 + i, 200 + i]), row)
        pool.free(row)
    assert pc.cached_pages <= 3
    assert pc.stats["evicted"] >= 2


# ---- scheduler: demand paging + preemption (host-side) ------------------------


def test_admission_allocates_prompt_pages_only():
    sched = Scheduler(n_slots=1, max_len=64, page_size=8)
    sched.submit(Request(0, np.zeros(12, np.int32), max_new=40))
    (slot, _, row, pfx) = sched.admit(0)[0]
    assert pfx == 0
    assert (row[:2] != TRASH_PAGE).all() and (row[2:] == TRASH_PAGE).all()
    assert sched.pool.pages_in_use == 2          # NOT ceil((12+40)/8) == 7
    growth, preempted = sched.ensure_capacity(8)  # writes [12, 20)
    assert preempted == [] and len(growth) == 1
    g_slot, g_row = growth[0]
    assert g_slot == slot and (g_row[:3] != TRASH_PAGE).all()
    assert sched.stats["demand_pages"] == 1


def test_preemption_requeues_youngest_deterministically():
    # 5 usable pages; two requests that each need 4 by end of life
    sched = Scheduler(n_slots=2, max_len=32, page_size=8, total_pages=6)
    for rid in range(2):
        sched.submit(Request(rid, np.zeros(12, np.int32), max_new=18))
    assert [p[0] for p in sched.admit(0)] == [0, 1]   # 2 pages each
    growth, preempted = sched.ensure_capacity(8)      # [12, 20): page 2 each
    assert preempted == [1]                           # youngest loses
    assert sched.queue[0].rid == 1                    # requeued at the head
    assert sched.stats["preemptions"] == 1
    # rid 0 keeps decoding to completion; rid 1 comes back afterwards
    sched.commit(0, np.full((18,), 7), eos_id=NO_EOS)
    assert [p[1].rid for p in sched.admit(1)] == [1]
    assert sched.pool.free_pages + sched.pool.pages_in_use == 5


def test_admission_never_aliases_matched_pages():
    """Regression: matched prefix pages are pinned BEFORE private
    allocation, so pool-pressure eviction can never reclaim a just-
    matched page and hand it back as the same request's private page
    (one physical page aliased as prefix AND suffix)."""
    sched = Scheduler(n_slots=1, max_len=48, page_size=8, total_pages=6,
                      slot_pages=5, prefix_cache=True)
    r0 = Request(0, np.arange(24), max_new=1)
    sched.submit(r0)
    sched.admit(0)
    sched.commit(0, np.asarray([7]), eos_id=NO_EOS)   # 3 cached, 2 free
    # warm prompt: 2 shared + 3 private wanted with 2 free -> the
    # eviction inside admission runs while the match is pinned
    sched.submit(Request(1, np.concatenate([np.arange(16),
                                            np.arange(100, 124)]),
                         max_new=1))
    (_, _, row, pfx) = sched.admit(0)[0]
    assert pfx == 16                  # match survived the eviction
    live = row[row != TRASH_PAGE]
    assert len(set(live.tolist())) == len(live)       # no aliased pages
    assert sched.pool.free_pages + sched.pool.pages_in_use == 5


def test_warm_admission_succeeds_at_exact_pool_fit():
    """The pin cannot starve the pool on its own: with the whole cache
    being the matched chain and ZERO slack pages, a warm admission still
    places (usable >= prompt_pages is the ctor invariant) — no livelock
    window behind the pin-before-alloc ordering."""
    sched = Scheduler(n_slots=1, max_len=40, page_size=8, total_pages=5,
                      slot_pages=4, prefix_cache=True)
    sched.submit(Request(0, np.arange(16), max_new=1))
    sched.admit(0)
    sched.commit(0, np.asarray([7]), eos_id=NO_EOS)   # 2 cached, 2 free
    sched.submit(Request(1, np.concatenate([np.arange(16),
                                            np.arange(100, 116)]),
                         max_new=1))
    placed = sched.admit(0)
    assert len(placed) == 1 and placed[0][3] == 16    # warm, exact fit
    assert sched.pool.free_pages == 0
    assert sched.pool.pages_in_use == 4


def test_hit_rate_counts_placed_admissions_only():
    """A blocked request re-matching every tick must not inflate the hit
    rate, and a match capped to zero shared pages is a miss."""
    sched = Scheduler(n_slots=2, max_len=32, page_size=8, total_pages=9,
                      prefix_cache=True)
    sched.submit(Request(0, np.arange(8), max_new=1))
    sched.admit(0)
    sched.commit(0, np.asarray([7]), eos_id=NO_EOS)
    # exact one-page prompt: the plen-1 cap drops the match -> miss
    sched.submit(Request(1, np.arange(8), max_new=1))
    sched.admit(1)
    assert sched.prefix_cache.stats["hits"] == 0
    assert sched.prefix_cache.stats["misses"] == 2
    assert sched.prefix_hit_rate == 0.0


# ---- engine: demand growth / preemption / trash rows --------------------------


@pytest.fixture(scope="module")
def tiny():
    return get_config("llama2-60m").smoke()


@pytest.fixture(scope="module")
def tiny_params(tiny):
    return registry.init_params(tiny, jax.random.PRNGKey(0))


def _assert_tokens_match(got, want, margins, tol=0.02, min_agree=0.8):
    """Margin-gated identity (the random-init near-tie caveat, as in
    tests/test_scheduler.py)."""
    got, want = np.asarray(got), np.asarray(want)
    n = min(len(got), len(want))
    neq = got[:n] != want[:n]
    if neq.any():
        assert (np.asarray(margins)[:n][neq] < tol).all(), \
            f"token mismatch at decisive steps: {np.nonzero(neq)[0]}"
    assert np.mean(~neq) >= min_agree


def test_demand_growth_across_page_boundary(tiny, tiny_params):
    """Decode crosses two page boundaries mid-stream; pages arrive on
    demand and tokens match the lockstep engine."""
    scfg = ServeConfig(batch_size=2, max_len=64, eos_id=NO_EOS,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=4)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, tiny.vocab_size, 10),
               rng.integers(0, tiny.vocab_size, 14)]
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    out = eng.generate(prompts, max_new=24)          # 10 + 24 crosses 16, 32
    assert eng.scheduler.stats["demand_pages"] >= 2
    assert eng.scheduler.stats["preemptions"] == 0
    solo = Engine(tiny, tiny_params,
                  ServeConfig(batch_size=1, max_len=64, eos_id=NO_EOS,
                              kv_cache_format="nvfp4"))
    for i in range(2):
        want = solo.generate([prompts[i]], max_new=24)[0]
        _assert_tokens_match(out[i], want, eng.margins[i])


def test_preemption_requeue_token_identity(tiny, tiny_params):
    """Pool sized so two long requests cannot coexist: the youngest is
    preempted mid-decode, requeued, and regenerates the SAME tokens it
    would have produced undisturbed (greedy recompute determinism)."""
    scfg = ServeConfig(batch_size=2, max_len=32, eos_id=NO_EOS,
                       kv_cache_format="nvfp4", page_size=8,
                       total_pages=6, decode_chunk=4)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, tiny.vocab_size, 12) for _ in range(2)]
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    res = eng.run([Request(i, prompts[i], max_new=18) for i in range(2)])
    st = eng.scheduler.stats
    assert st["preemptions"] >= 1 and st["completed"] == 2
    assert eng.prefill_compiles == 1 and eng.decode_compiles == 1
    for i in range(2):
        solo = eng.run([Request(i, prompts[i], max_new=18)])
        _assert_tokens_match(res[i], solo[i], eng.margins[i])


def test_freed_slot_rows_point_at_trash(tiny, tiny_params):
    """Regression: after a full trace every slot's page-table row in the
    engine's carry is back on TRASH_PAGE and the pool holds no pages."""
    scfg = ServeConfig(batch_size=2, max_len=64, eos_id=NO_EOS,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=4)
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    rng = np.random.default_rng(2)
    eng.generate([rng.integers(0, tiny.vocab_size, 8) for _ in range(2)],
                 max_new=4)
    tables = [np.asarray(c.page_table) for c in jax.tree_util.tree_leaves(
        eng._last_carry,
        is_leaf=lambda x: isinstance(x, PagedKVCache))
        if isinstance(c, PagedKVCache)]
    assert tables and all((t == TRASH_PAGE).all() for t in tables)
    assert eng.scheduler.pool.pages_in_use == 0


# ---- exactness: warm prefix == cold start -------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
def test_warm_prefix_bit_identical_to_cold_start(tiny, tiny_params, fmt):
    """The acceptance claim: a warm admission skips >= the matched full
    pages of prefill AND its greedy tokens are BIT-identical to a cold
    start of the same prompt — RtN pages are deterministic and the
    suffix program attends through them for cold (pfx=0) and warm alike.
    No recompilation across warm/cold admissions."""
    scfg = ServeConfig(batch_size=2, max_len=64, eos_id=NO_EOS,
                       kv_cache_format=fmt, page_size=16, decode_chunk=4,
                       prefix_cache=True)
    rng = np.random.default_rng(3)
    sys_prompt = rng.integers(0, tiny.vocab_size, 36)   # 2 full pages + 4
    prompts = [np.concatenate([sys_prompt,
                               rng.integers(0, tiny.vocab_size, 5)])
               for _ in range(3)]
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    res = eng.run([Request(i, prompts[i], max_new=6, arrival=i)
                   for i in range(3)])
    sched = eng.scheduler
    assert sched.prefix_cache.stats["hits"] == 2
    # each warm admission skipped exactly the 2 matched full pages
    assert sched.stats["prefix_tokens_skipped"] == 2 * 2 * 16
    assert sched.stats["prefilled_tokens"] == sum(
        len(p) for p in prompts) - 2 * 2 * 16
    assert eng.prefill_suffix_compiles == 1 and eng.decode_compiles == 1
    assert eng.prefill_compiles == 0        # all admissions via suffix path
    for i in range(1, 3):                   # warm rids vs solo cold starts
        solo = eng.run([Request(i, prompts[i], max_new=6)])
        np.testing.assert_array_equal(res[i], solo[i])
    assert eng.prefill_suffix_compiles == 1     # solo runs retraced nothing


def test_full_prompt_cached_keeps_suffix_nonempty(tiny, tiny_params):
    """A prompt whose EVERY page is cached still recomputes its tail page
    (match is capped at plen - 1 tokens) so sampling has logits."""
    scfg = ServeConfig(batch_size=2, max_len=64, eos_id=NO_EOS,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=4, prefix_cache=True)
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, tiny.vocab_size, 32)       # exactly 2 pages
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    res = eng.run([Request(0, prompt, max_new=4, arrival=0),
                   Request(1, prompt, max_new=4, arrival=1)])
    assert eng.scheduler.stats["prefix_tokens_skipped"] == 16   # 1 page only
    np.testing.assert_array_equal(res[0], res[1])


def test_intra_tick_sharing_same_arrival(tiny, tiny_params):
    """Two identical-prefix requests admitted in the SAME tick: the
    second one already shares the first's pages (insert-at-admission +
    in-order prefill)."""
    scfg = ServeConfig(batch_size=2, max_len=64, eos_id=NO_EOS,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=4, prefix_cache=True)
    rng = np.random.default_rng(5)
    shared = rng.integers(0, tiny.vocab_size, 20)
    prompts = [np.concatenate([shared,
                               rng.integers(0, tiny.vocab_size, 4)])
               for _ in range(2)]
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    res = eng.run([Request(i, prompts[i], max_new=4) for i in range(2)])
    assert eng.scheduler.stats["prefix_tokens_skipped"] == 16
    solo = eng.run([Request(1, prompts[1], max_new=4)])
    np.testing.assert_array_equal(res[1], solo[1])


def test_prefix_cache_rejects_unsupported_configs(tiny, tiny_params):
    swa = dataclasses.replace(tiny, sliding_window=32)
    with pytest.raises(NotImplementedError, match="sliding window"):
        ContinuousEngine(swa, tiny_params,
                         ServeConfig(batch_size=2, max_len=64,
                                     page_size=16, prefix_cache=True))


# ---- QAF trainer -> packed serving artifact -----------------------------------


def test_trainer_exports_packed_artifact_roundtrip(tiny, tmp_path):
    """Trainer.run finale packs the GEMM weights and checkpoints the
    4-bit artifact; restoring it into the Engine serves tokens identical
    to packing the restored bf16 weights at engine build."""
    from repro.data.pipeline import DataConfig
    from repro.train import TrainConfig, Trainer, TrainerConfig

    ck = str(tmp_path / "ck")
    trainer = Trainer(tiny, fqt.nvfp4_paper_config(), TrainConfig(remat=False),
                      TrainerConfig(total_steps=3, ckpt_every=100,
                                    ckpt_dir=ck),
                      DataConfig(vocab_size=tiny.vocab_size, seq_len=32,
                                 global_batch=4))
    state = trainer.run(jax.random.PRNGKey(0))
    assert any(e["kind"] == "export_packed" for e in trainer.events)

    spec = fqt.qaf_config().fwd_w
    template = pack_model_params(
        tiny, registry.init_params(tiny, jax.random.PRNGKey(1)), spec)
    step, packed = ckpt.restore_latest(ck + "/serve_packed", template)
    assert step == 3 and packed is not None

    scfg = ServeConfig(batch_size=2, max_len=64, eos_id=NO_EOS)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, tiny.vocab_size, 8) for _ in range(2)]
    from_artifact = Engine(tiny, packed, scfg, pack_weights=False)
    from_bf16 = Engine(tiny, state.params, scfg)       # packs at build
    out_a = from_artifact.generate(prompts, max_new=6)
    out_b = from_bf16.generate(prompts, max_new=6)
    for a, b in zip(out_a, out_b):
        np.testing.assert_array_equal(a, b)


def test_bf16_baseline_trainer_exports_nothing(tiny, tmp_path):
    """A run with no quantized forward (bf16 baseline) has no packed-
    serving story — it must not silently ship a lossy 4-bit artifact."""
    import os
    from repro.data.pipeline import DataConfig
    from repro.train import TrainConfig, Trainer, TrainerConfig

    ck = str(tmp_path / "ck_bf16")
    trainer = Trainer(tiny, fqt.bf16_config(), TrainConfig(remat=False),
                      TrainerConfig(total_steps=2, ckpt_every=100,
                                    ckpt_dir=ck),
                      DataConfig(vocab_size=tiny.vocab_size, seq_len=32,
                                 global_batch=4))
    trainer.run(jax.random.PRNGKey(0))
    assert not os.path.exists(os.path.join(ck, "serve_packed"))
    assert not any(e["kind"] == "export_packed" for e in trainer.events)
