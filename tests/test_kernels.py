"""Pallas kernels (interpret mode) vs pure-jnp oracles.

Bit-equality is asserted for quantization outputs (codes/scales); matmul
results are allclose (accumulation order differs between tiled Pallas
accumulation and XLA's single dot).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # offline container: deterministic shim
    from _hyp import given, settings, strategies as st

from repro.core import fqt
from repro.core.quantize import BlockQuantSpec, NVFP4, MXFP4, block_quantize
from repro.kernels import ops, ref

I = dict(interpret=True)


def _rand(shape, seed=0, scale=1.0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(dtype))


SPECS = [
    NVFP4,
    MXFP4,
    NVFP4.with_rounding(stochastic=True),
    MXFP4.with_rounding(stochastic=True),
    BlockQuantSpec(scale_fmt="e3m4", block=8, two_level=False),
]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: (
    f"{s.scale_fmt}-b{s.block}-{'sr' if s.stochastic else 'rtn'}"))
@pytest.mark.parametrize("shape", [(8, 32), (128, 128), (64, 256), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_matches_ref(spec, shape, dtype):
    x = _rand(shape, seed=hash((shape, str(dtype))) % 2**31).astype(dtype) * 3
    rbits = (jax.random.bits(jax.random.PRNGKey(5), shape=shape,
                             dtype=jnp.uint32) if spec.stochastic else None)
    codes_k, scales_k = ops.block_quantize(x, spec, rbits=rbits, **I)
    codes_r, scales_r = ref.block_quant_ref(x, spec, rbits=rbits, axis=-1)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_array_equal(np.asarray(scales_k), np.asarray(scales_r))


def test_quant_kernel_matches_core_block_quantize():
    """Kernel semantics == repro.core.quantize.block_quantize (RtN)."""
    x = _rand((64, 128), 3, 2.5)
    codes_k, scales_k = ops.block_quantize(x, NVFP4, **I)
    qt = block_quantize(x, NVFP4, axis=-1)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(qt.codes))
    np.testing.assert_array_equal(np.asarray(scales_k),
                                  np.asarray(qt.scales, np.float32))


@pytest.mark.parametrize("shape_mnk", [(32, 32, 32), (128, 128, 256),
                                       (64, 48, 512), (16, 128, 64)])
def test_block_matmul_matches_ref(shape_mnk):
    M, N, K = shape_mnk
    a = _rand((M, K), 11)
    b = _rand((K, N), 12)
    ac, asc = ref.block_quant_ref(a, NVFP4, axis=1)
    bc, bsc = ref.block_quant_ref(b, NVFP4, axis=0)
    ts = ref.tensor_scale_ref(a, NVFP4) * ref.tensor_scale_ref(b, NVFP4)
    out_k = ops.block_matmul(ac, asc, bc, bsc, ts, block=16, **I)
    out_r = ref.block_matmul_ref(ac, asc, bc, bsc, ts, 16)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sr", [False, True])
@pytest.mark.parametrize("shape_mnk", [(64, 64, 64), (128, 96, 256)])
def test_fused_quant_matmul_matches_ref(shape_mnk, sr):
    M, N, K = shape_mnk
    a = _rand((M, K), 21, 1.5)
    b = _rand((K, N), 22, 0.7)
    spec = NVFP4.with_rounding(stochastic=sr)
    arb = (jax.random.bits(jax.random.PRNGKey(1), shape=(M, K),
                           dtype=jnp.uint32) if sr else None)
    brb = (jax.random.bits(jax.random.PRNGKey(2), shape=(K, N),
                           dtype=jnp.uint32) if sr else None)
    out_k = ops.fused_quant_matmul(a, b, spec, spec, a_rbits=arb,
                                   b_rbits=brb, **I)
    out_r = ref.fused_quant_matmul_ref(a, b, spec, spec, a_rbits=arb,
                                       b_rbits=brb)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


def test_fqt_jnp_vs_pallas_forward():
    """The two fp4_matmul impls produce identical quantized operands; outputs
    agree to accumulation order."""
    x, w = _rand((64, 128), 31), _rand((128, 96), 32)
    y_j = fqt.fp4_matmul(x, w, cfg=fqt.nvfp4_paper_config("jnp"),
                         seed=jnp.uint32(9))
    y_p = fqt.fp4_matmul(x, w, cfg=fqt.nvfp4_paper_config("pallas"),
                         seed=jnp.uint32(9))
    np.testing.assert_allclose(np.asarray(y_j), np.asarray(y_p),
                               rtol=2e-5, atol=2e-5)


def test_fqt_jnp_vs_pallas_grads():
    """SR streams are shared between impls => same stochastic decisions."""
    x, w = _rand((64, 64), 33), _rand((64, 64), 34)
    c = _rand((64, 64), 35)

    def grads(impl):
        cfg = fqt.nvfp4_paper_config(impl)

        def loss(x, w):
            return jnp.sum(fqt.fp4_matmul(x, w, cfg=cfg, seed=jnp.uint32(4)) * c)
        return jax.grad(loss, argnums=(0, 1))(x, w)

    (dxj, dwj), (dxp, dwp) = grads("jnp"), grads("pallas")
    np.testing.assert_allclose(np.asarray(dxj), np.asarray(dxp),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dwj), np.asarray(dwp),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
def test_fused_matmul_property(mb, nb, kb, seed):
    """Random block-multiple shapes: kernel == oracle."""
    M, N, K = 8 * mb, 8 * nb, 16 * kb
    a = _rand((M, K), seed % 1000, 1.1)
    b = _rand((K, N), seed % 997, 0.9)
    out_k = ops.fused_quant_matmul(a, b, NVFP4, NVFP4, **I)
    out_r = ref.fused_quant_matmul_ref(a, b, NVFP4, NVFP4)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


def test_kernel_vmem_tiling_shapes():
    """Tiles must divide dims; uneven dims fall back to full-dim tiles."""
    a = _rand((24, 48), 41)
    b = _rand((48, 40), 42)
    out_k = ops.fused_quant_matmul(a, b, NVFP4, NVFP4, **I)
    out_r = ref.fused_quant_matmul_ref(a, b, NVFP4, NVFP4)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
