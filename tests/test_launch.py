"""Launch-layer unit tests: cell specs, skip rules, model-FLOPs accounting,
roofline math (no 512-device mesh needed — that's the dry-run's job)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch import roofline as rl
from repro.launch import specs
from repro.models.config import SHAPES_BY_NAME


def test_skip_rules():
    long = SHAPES_BY_NAME["long_500k"]
    assert specs.skip_reason(get_config("llama3-405b"), long)
    assert specs.skip_reason(get_config("qwen2.5-32b"), long)
    # sub-quadratic archs run 500k decode
    assert specs.skip_reason(get_config("zamba2-1.2b"), long) is None
    assert specs.skip_reason(get_config("xlstm-125m"), long) is None
    assert specs.skip_reason(get_config("mixtral-8x7b"), long) is None
    # everything runs train
    for a in ARCH_IDS:
        assert specs.skip_reason(get_config(a),
                                 SHAPES_BY_NAME["train_4k"]) is None


def test_params_struct_no_allocation():
    """eval_shape only — must hold even for llama3-405b on this laptop."""
    cfg = get_config("llama3-405b")
    ps = specs.params_struct(cfg)
    n = sum(x.size for x in jax.tree.leaves(ps))
    assert 390e9 < n < 430e9, n / 1e9
    assert all(isinstance(x, jax.ShapeDtypeStruct)
               for x in jax.tree.leaves(ps))


def test_batch_struct_shapes():
    cfg = get_config("whisper-base")
    b = specs.batch_struct(cfg, SHAPES_BY_NAME["train_4k"])
    assert b["tokens"].shape == (256, 4097)
    assert b["frames"].shape == (256, cfg.enc_seq, cfg.d_model)


def test_model_flops_moe_active_params():
    """MoE model-FLOPs must use ACTIVE params (top_k/E of expert weight)."""
    cfg = get_config("mixtral-8x7b")
    ps = specs.params_struct(cfg)
    shape = SHAPES_BY_NAME["train_4k"]
    mf = rl.model_flops(cfg, ps, shape)
    total = sum(x.size for x in jax.tree.leaves(ps))
    # mixtral: ~47B total, ~13B active -> model flops well below 6*N_total*D
    assert mf < 6.0 * total * shape.tokens * 0.45
    assert mf > 6.0 * total * shape.tokens * 0.15


def test_roofline_terms_and_bottleneck():
    r = rl.Roofline(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=50e9 / 2,
                    chips=256, model_flops=197e12 * 256 * 0.5)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.t_bound == pytest.approx(2.0)
    # model_flops / (flops_per_device × chips) = 0.5 by construction
    assert r.useful_fraction == pytest.approx(0.5)
    assert r.mfu_bound == pytest.approx(
        r.model_flops / (256 * rl.PEAK_FLOPS * 2.0))


def test_decode_carry_structs_all_archs():
    """make_decode_state eval_shapes for every arch x decode shape."""
    shape = SHAPES_BY_NAME["decode_32k"]
    for a in ("mixtral-8x7b", "zamba2-1.2b", "xlstm-125m", "whisper-base",
              "tinyllama-1.1b"):
        cfg = get_config(a)
        c = specs.decode_carry_struct(cfg, shape)
        leaves = jax.tree.leaves(c)
        assert leaves, a
        # SWA rolling buffer stays window-sized
        if cfg.sliding_window:
            kv = [x for x in leaves if x.ndim == 4 and x.shape[1] > 1]
            assert all(x.shape[2] <= cfg.sliding_window for x in kv)
