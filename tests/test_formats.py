"""Unit + property tests for the generic minifloat quantizers."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
import ml_dtypes
try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # offline container: deterministic shim
    from _hyp import given, settings, strategies as st

from repro.core import formats
from repro.core.formats import (E2M1, E4M3, E5M2, E8M0, E3M4, get_format,
                                quantize_rtn, quantize_sr)

jax.config.update("jax_enable_x64", False)


# ---- grids -------------------------------------------------------------------

def test_e2m1_grid():
    np.testing.assert_allclose(E2M1.grid(), [0, .5, 1, 1.5, 2, 3, 4, 6])


def test_e4m3_props():
    assert E4M3.max == 448.0
    assert E4M3.smallest_subnormal == pytest.approx(2.0 ** -9)


def test_e8m0_props():
    assert E8M0.max == 2.0 ** 127
    assert not E8M0.signed


@pytest.mark.parametrize("name,mldt", [
    ("e2m1", ml_dtypes.float4_e2m1fn),
    ("e4m3", ml_dtypes.float8_e4m3fn),
    ("e5m2", ml_dtypes.float8_e5m2),
    ("e3m4", ml_dtypes.float8_e3m4),
])
def test_rtn_matches_ml_dtypes(name, mldt):
    """Our generic RtN must agree bit-exactly with ml_dtypes saturating casts
    on finite, in-range inputs."""
    fmt = get_format(name)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(4096).astype(np.float32) * fmt.max * 0.5
    ours = np.asarray(quantize_rtn(jnp.asarray(x), fmt))
    # ml_dtypes astype is RtN-even but non-saturating at the very top;
    # restrict to clearly in-range values for the comparison.
    mask = np.abs(x) <= fmt.max * 0.99
    theirs = x.astype(mldt).astype(np.float32)
    np.testing.assert_array_equal(ours[mask], theirs[mask])


def test_rtn_saturates():
    out = quantize_rtn(jnp.asarray([1e9, -1e9, 7.0, -6.5]), E2M1)
    np.testing.assert_allclose(out, [6, -6, 6, -6])


def test_rtn_on_grid():
    """Every RtN output is a grid point; error <= half ulp."""
    rng = np.random.default_rng(1)
    for fmt in [E2M1, E4M3, E3M4, get_format("e1m6"), get_format("e6m1")]:
        x = rng.uniform(-fmt.max, fmt.max, 8192).astype(np.float32)
        q = np.asarray(quantize_rtn(jnp.asarray(x), fmt))
        assert formats.snap_distance(q, fmt).max() == 0.0, fmt.name
        # nearest-ness: |x - q| must be <= distance to any other grid point
        d = formats.snap_distance(x.astype(np.float64), fmt)
        np.testing.assert_allclose(np.abs(x - q), d, rtol=1e-5, atol=1e-7)


def test_rtn_ties_to_even():
    # E2M1: 2.5 ties between 2 (even mantissa) and 3 (odd) -> 2
    out = quantize_rtn(jnp.asarray([2.5, 3.5, 1.25, 1.75, 0.25]), E2M1)
    np.testing.assert_allclose(out, [2.0, 4.0, 1.0, 2.0, 0.0])


def test_sr_on_grid():
    rng = np.random.default_rng(2)
    x = rng.uniform(-6, 6, 8192).astype(np.float32)
    q = np.asarray(quantize_sr(jnp.asarray(x), E2M1, jax.random.PRNGKey(0)))
    assert formats.snap_distance(q, E2M1).max() == 0.0
    # SR never moves by more than one grid gap
    lo_hi_gap = 2.0  # largest E2M1 gap (4 -> 6)
    assert np.abs(q - x).max() <= lo_hi_gap


@settings(max_examples=30, deadline=None)
@given(st.floats(min_value=-5.875, max_value=5.875, allow_nan=False,
                 allow_infinity=False, width=32))
def test_sr_unbiased(val):
    """E[Q_SR(x)] == x for in-range x (the core property behind the paper's
    split-rounding scheme and the §4 analysis)."""
    n = 4096
    x = jnp.full((n,), val, dtype=jnp.float32)
    q = quantize_sr(x, E2M1, jax.random.PRNGKey(42))
    mean = float(jnp.mean(q))
    # standard error of the mean of a Bernoulli mixture with gap <= 2
    se = 2.0 / np.sqrt(n)
    assert abs(mean - val) < 5 * se + 1e-6


def test_sr_probabilities():
    """P(round up) == fractional position between neighbours."""
    # 2.75 lies between 2 and 3: p(3) = 0.75
    x = jnp.full((20000,), 2.75, dtype=jnp.float32)
    q = quantize_sr(x, E2M1, jax.random.PRNGKey(7))
    frac_up = float(jnp.mean(q == 3.0))
    assert abs(frac_up - 0.75) < 0.02
    assert set(np.unique(np.asarray(q))) <= {2.0, 3.0}


def test_e8m0_floor():
    x = jnp.asarray([1.0, 1.5, 2.0, 3.9, 0.3])
    np.testing.assert_allclose(formats.e8m0_floor(x), [1, 1, 2, 2, 0.25])
