"""Workload generator + simulated-clock metrics + bench-JSON merge (PR 8).

Host-side properties (no engine, no jax):
  * DETERMINISM: the same ``WorkloadConfig`` produces the same trace
    byte-for-byte (``trace_fingerprint``); a different seed does not;
  * TENANT ISOLATION: each tenant draws from its own child PRNG stream,
    so appending a tenant never perturbs another tenant's trace;
  * trace shape: sequential rids in (arrival, tenant, intra-tick) order,
    shared system prompts, prompt-length mixtures, burst overlays, and
    the deadline/abort_at/timeout arithmetic;
  * statistical sanity of the Poisson arrivals and the length mixture
    (seeded draws — the bounds are loose but the numbers never move);
  * nearest-rank percentile math + the MetricsRecorder lifecycle
    arithmetic (TTFT/TPOT/goodput, preemption-stable first-token);
  * benchmarks.run._merge_bench_json replaces groups at GROUP
    granularity and never clobbers the rest of BENCH_serve.json.
"""
import os
import sys

import numpy as np
import pytest

from repro.serve import (TenantSpec, WorkloadConfig, as_requests,
                         generate_workload, trace_fingerprint)
from repro.serve.metrics import (MetricsRecorder, percentile,
                                 percentile_summary)
from repro.serve.scheduler import Request

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
from benchmarks.run import _merge_bench_json  # noqa: E402


def _two_tenants(seed=0, ticks=16):
    return WorkloadConfig(tenants=(
        TenantSpec("chat", rate=0.6, prompt_lens=(4, 8),
                   system_prompt_len=8, max_new=6, deadline_slack=20),
        TenantSpec("batch", rate=0.3, prompt_lens=(16,), max_new=4,
                   abort_prob=0.5, abort_after=3, timeout=24),
    ), ticks=ticks, seed=seed, vocab=128)


# ---- determinism ---------------------------------------------------------------


def test_trace_is_deterministic_byte_for_byte():
    w = _two_tenants(seed=11)
    assert trace_fingerprint(generate_workload(w)) == \
        trace_fingerprint(generate_workload(w))


def test_seed_changes_the_trace():
    a = trace_fingerprint(generate_workload(_two_tenants(seed=1)))
    b = trace_fingerprint(generate_workload(_two_tenants(seed=2)))
    assert a != b


def test_tenant_streams_are_isolated():
    """Appending a tenant must not perturb the existing tenants' events
    (child streams are keyed by (seed, tenant index), not shared)."""
    base = generate_workload(_two_tenants(seed=5))
    extended = generate_workload(WorkloadConfig(
        tenants=_two_tenants(seed=5).tenants + (
            TenantSpec("extra", rate=1.5, prompt_lens=(2,)),),
        ticks=16, seed=5, vocab=128))

    def key(e):
        return (e.tenant, e.arrival, e.max_new, e.deadline, e.abort_at,
                e.timeout, e.prompt.tobytes())

    for name in ("chat", "batch"):
        assert [key(e) for e in base if e.tenant == name] == \
            [key(e) for e in extended if e.tenant == name]


# ---- trace shape ---------------------------------------------------------------


def test_rids_sequential_and_arrivals_sorted():
    evs = generate_workload(_two_tenants(seed=3))
    assert [e.rid for e in evs] == list(range(len(evs)))
    arr = [e.arrival for e in evs]
    assert arr == sorted(arr)


def test_system_prompt_shared_within_tenant():
    evs = [e for e in generate_workload(_two_tenants(seed=4))
           if e.tenant == "chat"]
    assert len(evs) >= 2          # seeded: the chat tenant does arrive
    sys_tok = evs[0].prompt[:8]
    for e in evs:
        np.testing.assert_array_equal(e.prompt[:8], sys_tok)
        assert len(e.prompt) - 8 in (4, 8)     # body from the mixture


def test_burst_overlay_fires_on_schedule():
    evs = generate_workload(WorkloadConfig(tenants=(
        TenantSpec("bursty", rate=0.0, prompt_lens=(4,),
                   burst_every=4, burst_size=2),), ticks=8, seed=0))
    assert len(evs) == 4                       # ticks 0 and 4, 2 each
    assert sorted(e.arrival for e in evs) == [0, 0, 4, 4]


def test_lifecycle_field_arithmetic():
    evs = generate_workload(WorkloadConfig(tenants=(
        TenantSpec("t", rate=1.0, prompt_lens=(4,), deadline_slack=10,
                   abort_prob=1.0, abort_after=3, timeout=7),),
        ticks=8, seed=2))
    assert evs
    for e in evs:
        assert e.deadline == e.arrival + 10
        assert e.abort_at == e.arrival + 3     # abort_prob == 1
        assert e.timeout == 7
    calm = generate_workload(WorkloadConfig(tenants=(
        TenantSpec("t", rate=1.0, prompt_lens=(4,)),), ticks=8, seed=2))
    assert all(e.deadline is None and e.abort_at is None
               and e.timeout is None for e in calm)


def test_as_requests_is_a_faithful_mapping():
    evs = generate_workload(_two_tenants(seed=6))
    reqs = as_requests(evs)
    assert all(isinstance(r, Request) for r in reqs)
    for e, r in zip(evs, reqs):
        assert (r.rid, r.max_new, r.arrival) == (e.rid, e.max_new, e.arrival)
        assert (r.deadline, r.abort_at, r.timeout) == \
            (e.deadline, e.abort_at, e.timeout)
        np.testing.assert_array_equal(r.prompt, e.prompt)


# ---- statistical sanity (seeded: loose bounds, frozen numbers) -----------------


def test_poisson_rate_sanity():
    evs = generate_workload(WorkloadConfig(tenants=(
        TenantSpec("t", rate=0.5, prompt_lens=(4,)),), ticks=400, seed=9))
    assert 120 <= len(evs) <= 280              # mean 200, sigma ~14


def test_prompt_mixture_respects_probs():
    evs = generate_workload(WorkloadConfig(tenants=(
        TenantSpec("t", rate=1.0, prompt_lens=(4, 32),
                   prompt_probs=(0.9, 0.1)),), ticks=200, seed=10))
    short = sum(1 for e in evs if len(e.prompt) == 4)
    assert len(evs) > 50
    assert short / len(evs) > 0.7              # 0.9 nominal, loose bound


# ---- validation ----------------------------------------------------------------


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="rate"):
        TenantSpec("t", rate=-0.1)
    with pytest.raises(ValueError, match="prompt_lens"):
        TenantSpec("t", prompt_lens=())
    with pytest.raises(ValueError, match="prompt_probs"):
        TenantSpec("t", prompt_lens=(4, 8), prompt_probs=(1.0,))
    with pytest.raises(ValueError, match="abort_prob"):
        TenantSpec("t", abort_prob=1.5)
    with pytest.raises(ValueError, match="tenant"):
        WorkloadConfig(tenants=())
    with pytest.raises(ValueError, match="tick"):
        WorkloadConfig(tenants=(TenantSpec("t"),), ticks=0)


# ---- nearest-rank percentiles --------------------------------------------------


def test_percentile_nearest_rank():
    vals = [10, 20, 30, 40]
    assert percentile(vals, 50) == 20          # ceil(.5*4) = 2nd smallest
    assert percentile(vals, 75) == 30
    assert percentile(vals, 95) == 40
    assert percentile(vals, 100) == 40         # p100 is the max
    assert percentile([7], 99) == 7
    assert np.isnan(percentile([], 50))
    with pytest.raises(ValueError, match="percentile"):
        percentile(vals, 0)
    with pytest.raises(ValueError, match="percentile"):
        percentile(vals, 101)


def test_percentile_summary_shape():
    s = percentile_summary([1.0, 2.0, 3.0])
    assert set(s) == {"p50", "p95", "p99", "mean", "max", "n"}
    assert s["n"] == 3 and s["max"] == 3.0 and s["p50"] == 2.0
    empty = percentile_summary([])
    assert empty["n"] == 0 and np.isnan(empty["p50"])


# ---- MetricsRecorder lifecycle arithmetic --------------------------------------


def test_recorder_ttft_tpot_goodput():
    m = MetricsRecorder()
    # rid 0: arrival 0, first token tick 3, done tick 7 with 5 tokens,
    # deadline 10 (met).  rid 1: arrival 2, first tick 6, done tick 10
    # with 3 tokens, deadline 8 (missed).  rid 2: cancelled while queued.
    m.submitted(0, 0, deadline=10)
    m.submitted(1, 2, deadline=8)
    m.submitted(2, 4)
    m.admitted(0, 1)
    m.first_token(0, 3)
    m.finished(0, 7, 5)
    m.admitted(1, 4)
    m.first_token(1, 6)
    m.finished(1, 10, 3)
    m.cancelled(2, 5, "queued", "timeout")
    assert sorted(m.ttfts()) == [3, 4]
    assert sorted(m.tpots()) == [1.0, 2.0]     # (7-3)/4, (10-6)/2
    assert m.goodput() == pytest.approx(1 / 3)  # rid 0 only, of 3 submitted
    s = m.summary()
    assert s["submitted"] == 3 and s["completed"] == 2 \
        and s["cancelled"] == 1
    assert s["ttft_ticks"]["p50"] == 3 and s["ttft_ticks"]["max"] == 4
    assert s["tpot_ticks"]["p99"] == 2.0


def test_recorder_preemption_keeps_first_emission():
    """Preemption replays the identical stream, so the FIRST admission
    and first-token ticks stand — re-admission never moves them."""
    m = MetricsRecorder()
    m.submitted(0, 0)
    m.admitted(0, 1)
    m.first_token(0, 2)
    m.admitted(0, 5)                           # re-admission after preempt
    m.first_token(0, 6)                        # replayed first token
    m.finished(0, 8, 4)
    assert m.requests[0]["admitted"] == 1
    assert m.ttfts() == [2]


def test_recorder_no_deadline_counts_as_on_time():
    m = MetricsRecorder()
    m.submitted(0, 0)
    m.first_token(0, 1)
    m.finished(0, 3, 2)
    assert m.goodput() == 1.0
    assert MetricsRecorder().goodput() == 0.0  # empty trace


# ---- BENCH_serve.json group-level merge ----------------------------------------


def test_merge_bench_json_is_group_granular():
    existing = {"benches": {"kv_cache": {"a": 1.0}, "traffic": {"old": 2.0}},
                "generated_by": "benchmarks.run --json",
                "custom_note": "keep me"}
    out = _merge_bench_json(existing, {"traffic": {"ttft_ticks_p50": 3.0},
                                       "lint": {"rules": 9.0}})
    assert out["benches"]["kv_cache"] == {"a": 1.0}          # untouched
    assert out["benches"]["traffic"] == {"ttft_ticks_p50": 3.0}  # replaced
    assert out["benches"]["lint"] == {"rules": 9.0}          # added
    assert out["custom_note"] == "keep me"                   # kept verbatim
    assert out["generated_by"] == "benchmarks.run --json"
    # a fresh/unreadable artifact degenerates to just the new groups
    fresh = _merge_bench_json({}, {"traffic": {"x": 1.0}})
    assert fresh["benches"] == {"traffic": {"x": 1.0}}
