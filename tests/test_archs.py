"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, asserting shapes + finiteness (assignment (f))."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.core import fqt
from repro.models import registry

QCFG = fqt.nvfp4_paper_config()
BF16 = fqt.bf16_config()


def _batch(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_tokens, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def smoke_state():
    """Cache (params, cfg) per arch across tests in this module."""
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch).smoke()
            params = registry.init_params(cfg, jax.random.PRNGKey(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch, smoke_state):
    cfg, params = smoke_state(arch)
    batch = _batch(cfg)
    logits, aux = registry.forward(params, cfg, QCFG, batch, seed=1)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # padded vocab ids masked
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e29


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, smoke_state):
    """One FQT train step: loss + grads finite, grads nonzero."""
    cfg, params = smoke_state(arch)
    batch = _batch(cfg)

    def loss(p):
        l, _ = registry.loss_fn(p, cfg, QCFG, batch, seed=2)
        return l

    l, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l)) and float(l) > 0
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in flat)
    gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                               for g in flat)))
    assert gnorm > 0


@pytest.mark.parametrize("arch", ["tinyllama_1p1b", "mixtral_8x7b",
                                  "zamba2_1p2b", "xlstm_125m",
                                  "whisper_base", "internvl2_26b"])
def test_decode_smoke(arch, smoke_state):
    """One decode step against a pre-allocated cache/state (one per family)."""
    cfg, params = smoke_state(arch)
    B, CACHE = 2, 64
    carry = registry.make_decode_state(cfg, B, CACHE)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, carry2 = registry.decode_step(params, cfg, QCFG, tok, carry,
                                          seed=3)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # second step advances
    logits2, _ = registry.decode_step(params, cfg, QCFG, tok, carry2, seed=4)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_fp4_vs_bf16_losses_close_at_init():
    """FP4 quantization is a perturbation, not a rewrite: at init the FQT
    loss should be within ~15%% of the bf16 loss (sanity on quant scale)."""
    cfg = get_config("tinyllama_1p1b").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l4, _ = registry.loss_fn(params, cfg, QCFG, batch, seed=0)
    l16, _ = registry.loss_fn(params, cfg, BF16, batch, seed=0)
    assert abs(float(l4) - float(l16)) / float(l16) < 0.15


def test_swa_equals_full_attention_within_window():
    """Mixtral SWA: with seq < window the result must equal full attention."""
    import dataclasses
    cfg = get_config("mixtral_8x7b").smoke()
    cfg_full = dataclasses.replace(cfg, sliding_window=None)
    params = registry.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, S=16)  # 16 < smoke window 64
    l1, _ = registry.loss_fn(params, cfg, BF16, batch, seed=0)
    l2, _ = registry.loss_fn(params, cfg_full, BF16, batch, seed=0)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
