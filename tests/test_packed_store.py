"""Quantize-once packed NVFP4 weight store: equivalence + regressions.

Covers the PR's acceptance criteria:
  * pack_e2m1/unpack_e2m1 round-trip (arithmetic codec, no float4 dtype);
  * PackedQuantizedTensor.dequant == QuantizedTensor.dequant BIT-exact;
  * batched pack_quantize slices == per-matrix fake-quant (the lax.scan
    invariant behind stacked layer weights);
  * fqt.fp4_matmul with a packed weight == fake-quant forward bit-exact
    (jnp impl) and == Pallas packed_block_matmul (interpret);
  * Engine.generate tokens identical packed vs fake-quant;
  * packed params tree save/restores through checkpoint/ckpt.py and is
    <= 0.6 bytes/param on disk for the GEMM weights;
  * regression: fused_quant_matmul honors spec_b's formats (it used to
    silently quantize B with spec_a's data/scale formats);
  * regression: shard_map compat wrapper importable and callable on this
    JAX version (jax.shard_map absent on 0.4.x).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core import fqt
from repro.core.quantize import (NVFP4, BlockQuantSpec, PackedQuantizedTensor,
                                 block_quantize, fake_quant, pack_e2m1,
                                 pack_quantize, pack_quantized, unpack_e2m1)
from repro.models import registry
from repro.serve import Engine, ServeConfig
from repro.serve.packing import (pack_model_params, param_count,
                                 weight_store_bytes)


def _rand(shape, seed=0, dtype=jnp.bfloat16):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape)
                       .astype(np.float32)).astype(dtype)


# ---- codec ------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pack_unpack_roundtrip_all_codes(dtype):
    """Every signed E2M1 grid value survives the nibble round-trip."""
    grid = np.array([0, .5, 1, 1.5, 2, 3, 4, 6], np.float32)
    vals = np.concatenate([grid, -grid]).astype(np.float32)
    x = jnp.asarray(vals, dtype)
    un = unpack_e2m1(pack_e2m1(x), dtype=dtype)
    np.testing.assert_array_equal(np.asarray(un, np.float32),
                                  np.asarray(x, np.float32))


def test_pack_requires_even_last_axis():
    with pytest.raises(ValueError, match="even"):
        pack_e2m1(jnp.zeros((4, 3)))


# ---- packed tensor equivalence ----------------------------------------------


@pytest.mark.parametrize("axis", [0, 1, -2, -1])
def test_packed_dequant_bit_exact(axis):
    x = _rand((64, 64), seed=1)
    qt = block_quantize(x, NVFP4, axis=axis)
    pq = pack_quantized(qt)
    assert pq.scales.dtype == jnp.float8_e4m3fn
    np.testing.assert_array_equal(
        np.asarray(pq.dequant(), np.float32),
        np.asarray(qt.dequant(), np.float32))


def test_pack_quantize_batched_matches_per_slice():
    """Stacked (L, K, N) packing must equal per-layer fake-quant: per-slice
    tensor scales, sliceable as a pytree (what lax.scan does)."""
    W = _rand((3, 32, 48), seed=2)
    pk = pack_quantize(W, NVFP4, axis=-2, batch_dims=1)
    for i in range(3):
        ref = fake_quant(W[i], NVFP4, axis=0)
        sl = jax.tree_util.tree_map(lambda a: a[i], pk)
        np.testing.assert_array_equal(np.asarray(sl.dequant(), np.float32),
                                      np.asarray(ref, np.float32))


def test_pack_quantize_batched_two_level_false():
    """two_level=False (MXFP4) must still give a batch-shaped tscale so
    stacked weights slice under lax.scan (regression: scalar tscale made
    MXFP4-packed serving crash at trace time)."""
    from repro.core.quantize import MXFP4
    W = _rand((3, 32, 48), seed=2)
    pk = pack_quantize(W, MXFP4, axis=-2, batch_dims=1)
    assert pk.tscale.shape == (3,)
    for i in range(3):
        ref = fake_quant(W[i], MXFP4, axis=0)
        sl = jax.tree_util.tree_map(lambda a: a[i], pk)
        np.testing.assert_array_equal(np.asarray(sl.dequant(), np.float32),
                                      np.asarray(ref, np.float32))


def test_engine_tokens_identical_mxfp4(tiny):
    params = registry.init_params(tiny, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_size=1, max_len=48)
    prompts = [np.random.default_rng(0).integers(0, tiny.vocab_size, 6)]
    qcfg = fqt.mxfp4_config()
    out_p = Engine(tiny, params, scfg, qcfg=qcfg).generate(prompts, max_new=4)
    out_f = Engine(tiny, params, scfg, qcfg=qcfg,
                   pack_weights=False).generate(prompts, max_new=4)
    np.testing.assert_array_equal(out_p[0], out_f[0])


def test_packed_bytes_per_param():
    W = _rand((256, 256), seed=3)
    pk = pack_quantize(W, NVFP4, axis=-2)
    bpp = pk.nbytes() / W.size
    assert bpp <= 0.6, bpp          # 4-bit codes + f8 scale per 16 = 0.5625


def test_packed_forward_bit_exact_jnp():
    x = _rand((16, 128), seed=4)
    w = _rand((128, 96), seed=5)
    cfg = fqt.qaf_config()
    y_fake = fqt.fp4_matmul(x, w, cfg=cfg)
    y_packed = fqt.fp4_matmul(x, pack_quantize(w, NVFP4, axis=-2), cfg=cfg)
    np.testing.assert_array_equal(np.asarray(y_fake, np.float32),
                                  np.asarray(y_packed, np.float32))


def test_packed_kernel_matches_jnp_path():
    x = _rand((64, 128), seed=6)
    w = _rand((128, 64), seed=7)
    pw = pack_quantize(w, NVFP4, axis=-2)
    y_jnp = fqt.fp4_matmul(x, pw, cfg=fqt.qaf_config())
    y_pal = fqt.fp4_matmul(x, pw, cfg=fqt.qaf_config(impl="pallas"))
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_jnp, np.float32),
                               rtol=1e-5, atol=1e-5)


# ---- serving engine ----------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    return get_config("llama2-60m").smoke()


def test_engine_tokens_identical_packed_vs_fake(tiny):
    params = registry.init_params(tiny, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, tiny.vocab_size, 8),
               rng.integers(0, tiny.vocab_size, 5)]
    packed = Engine(tiny, params, scfg)                     # default: packed
    fake = Engine(tiny, params, scfg, pack_weights=False)
    out_p = packed.generate(prompts, max_new=8)
    out_f = fake.generate(prompts, max_new=8)
    assert any(isinstance(l, PackedQuantizedTensor)
               for l in jax.tree_util.tree_leaves(
                   packed.params,
                   is_leaf=lambda x: isinstance(x, PackedQuantizedTensor)))
    for a, b in zip(out_p, out_f):
        np.testing.assert_array_equal(a, b)


def test_engine_bf16_config_stays_unpacked(tiny):
    params = registry.init_params(tiny, jax.random.PRNGKey(0))
    eng = Engine(tiny, params, ServeConfig(batch_size=2, max_len=64),
                 qcfg=fqt.bf16_config())
    assert not any(isinstance(l, PackedQuantizedTensor)
                   for l in jax.tree_util.tree_leaves(
                       eng.params,
                       is_leaf=lambda x: isinstance(x, PackedQuantizedTensor)))


# ---- checkpoint export --------------------------------------------------------


def test_packed_checkpoint_roundtrip_and_size(tiny, tmp_path):
    params = registry.init_params(tiny, jax.random.PRNGKey(0))
    packed = pack_model_params(tiny, params, fqt.qaf_config().fwd_w)
    ckpt.save(str(tmp_path), 1, packed)
    restored = ckpt.restore(str(tmp_path), 1, packed)
    for a, b in zip(jax.tree_util.tree_leaves(packed),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # the packed GEMM weights are <= 0.6 bytes/param in the store
    packed_leaves = [l for l in jax.tree_util.tree_leaves(
        packed, is_leaf=lambda x: isinstance(x, PackedQuantizedTensor))
        if isinstance(l, PackedQuantizedTensor)]
    stored = sum(l.nbytes() for l in packed_leaves)
    n = sum(int(np.prod(l.shape)) for l in packed_leaves)
    assert stored / n <= 0.6
    # and the whole artifact shrank vs the bf16 tree
    disk = sum(os.path.getsize(os.path.join(r, f))
               for r, _, fs in os.walk(tmp_path) for f in fs)
    assert disk < weight_store_bytes(params)
    assert param_count(packed) == param_count(params)


# ---- regressions --------------------------------------------------------------


def test_fused_quant_matmul_honors_spec_b():
    """fused_quant_matmul used to build the kernel from spec_a's formats
    only, silently misquantizing B when spec_b differed."""
    from repro.kernels import ops, ref
    e8 = BlockQuantSpec(data_fmt="e2m1", scale_fmt="e8m0", block=16,
                        two_level=False)
    a = _rand((32, 64), seed=8, dtype=jnp.float32)
    b = _rand((64, 32), seed=9, dtype=jnp.float32)
    out_k = ops.fused_quant_matmul(a, b, NVFP4, e8)
    out_r = ref.fused_quant_matmul_ref(a, b, NVFP4, e8)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)
    # and the mirrored case (spec_a exotic, spec_b NVFP4)
    out_k2 = ops.fused_quant_matmul(a, b, e8, NVFP4)
    out_r2 = ref.fused_quant_matmul_ref(a, b, e8, NVFP4)
    np.testing.assert_allclose(np.asarray(out_k2), np.asarray(out_r2),
                               rtol=1e-5, atol=1e-5)


def test_fused_quant_matmul_block_mismatch_raises():
    from repro.kernels import ops
    from repro.core.quantize import MXFP4
    a = _rand((32, 64), seed=8, dtype=jnp.float32)
    b = _rand((64, 32), seed=9, dtype=jnp.float32)
    with pytest.raises(ValueError, match="block"):
        ops.fused_quant_matmul(a, b, NVFP4, MXFP4)   # block 16 vs 32


def test_shard_map_compat_single_device():
    """repro.distributed.compat.shard_map works on this JAX version (the
    jax.shard_map attribute does not exist on 0.4.x)."""
    from repro.distributed.compat import shard_map
    mesh = jax.make_mesh((1,), ("pipe",))
    from jax.sharding import PartitionSpec as P

    def f(x):
        return jax.lax.psum(x, "pipe")

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P(),
                            axis_names=frozenset({"pipe"}),
                            check_vma=False))(jnp.ones((4, 4)))
    np.testing.assert_array_equal(np.asarray(out), np.ones((4, 4)))
