"""Mesh-native serving: ONE code path from 1 to N devices.

The contract under test:
  * the 1-device mesh is the DEGENERATE CASE of the same code path — an
    engine built with an explicit ``--mesh`` spec ("tp=1") produces
    bit-identical tokens to the default engine (no ``if sharded:`` fork
    exists to diverge), across nvfp4/fp8/bf16 KV-cache formats;
  * the no-recompile guarantees survive the mesh: three compiled programs
    (prefill / warm-prefix prefill / decode), jit cache sizes == 1 across
    admissions, slot reuse and repeated runs;
  * the spec-derivation layer (distributed/specs.py) keeps block-scale
    axes CONGRUENT with nibble-code axes, normalizes size-1 mesh axes and
    trailing Nones (GSPMD's canonical form — spec equality keys the jit
    compile cache), and DIAGNOSES dropped axes instead of silently
    replicating;
  * real TP=2/4 semantics (subprocess, forced host devices — see
    conftest.run_multidev): sharded engines emit exactly the 1-device
    token streams, column-parallel ``tp_fp4_matmul`` is bitwise equal to
    the 1-device packed forward, row-parallel matches to psum reordering,
    and the packed all-gather round-trips the ~4.5 bits/param wire format.
"""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd, specs as pspecs
from repro.models import registry
from repro.serve import ContinuousEngine, Engine, Request, ServeConfig

FMTS = ("nvfp4", "fp8", "bf16")
NO_EOS = -1


# ---- spec derivation (pure logic, no devices) ---------------------------------


def test_parse_mesh_spec():
    assert pspecs.parse_mesh_spec(None) == {"model": 1}
    assert pspecs.parse_mesh_spec("") == {"model": 1}
    assert pspecs.parse_mesh_spec("tp=2") == {"model": 2}
    assert pspecs.parse_mesh_spec("dp=2,tp=4") == {"data": 2, "model": 4}
    assert pspecs.parse_mesh_spec("fsdp=2") == {"data": 2, "model": 1}
    for bad in ("tp=0", "tp=-1", "ep=2", "tp", "tp=2;dp=2"):
        with pytest.raises(ValueError):
            pspecs.parse_mesh_spec(bad)


def test_spec_canonical_form():
    """Size-1 axes and trailing Nones must normalize away: GSPMD reports
    jit OUTPUT shardings in canonical form, and PartitionSpec equality
    keys the compile cache — a non-canonical input spec means a spurious
    recompile on call 2."""
    assert pspecs.strip_trailing_none((None, None)) == ()
    assert pspecs.strip_trailing_none(("model", None)) == ("model",)
    # size-1 mesh axis == replication
    assert pspecs.divisible_axes(("model", None), (8, 8),
                                 {"model": 1}) == ()
    out = pspecs.packed_leaf_specs((None, "model"), (64, 32), axis=-2,
                                   block=16, axis_sizes={"model": 1})
    assert out == {"packed": (), "scales": (), "tscale": ()}


def test_packed_leaf_specs_congruent():
    """Scale specs are DERIVED from code specs — congruent by construction
    across kinds/shapes/tp sizes; a dim that cannot shard on every leaf
    is replicated on all of them WITH a diagnostic naming the leaf."""
    for tp in (2, 4):
        out = pspecs.packed_leaf_specs((None, "model"), (64, 32), axis=-2,
                                       block=16, axis_sizes={"model": tp})
        assert out["packed"] == (None, "model")
        assert pspecs.congruent(out["packed"], out["scales"])
    # odd output dim: packed size 15 not divisible by 2 -> dropped, named
    drops = []
    out = pspecs.packed_leaf_specs((None, "model"), (64, 30), axis=-2,
                                   block=16, axis_sizes={"model": 2},
                                   path="layers/attn/wq", drops=drops)
    assert out["packed"] == () and out["scales"] == ()
    assert drops and "layers/attn/wq" in drops[0]


def test_wire_format_accounting():
    """NVFP4 wire format: 4-bit codes + one f8 scale per 16 = 4.5 bits."""
    assert pspecs.packed_wire_bits_per_param() == 4.5
    assert pspecs.packed_gather_ratio() == pytest.approx(16 / 4.5)


def test_divisible_diagnoses_dropped_axes(caplog):
    """Satellite: no silent replication fallback — named leaves log (or
    raise, strict=True) a diagnostic identifying the leaf path."""
    mesh = shd.make_serve_mesh(None)

    class _M:                                   # 2-device stand-in mesh
        axis_names = ("model",)

        class devices:
            shape = (2,)

    with caplog.at_level(logging.WARNING, "repro.distributed.sharding"):
        spec = shd._divisible(P("model"), (15,), _M(), path="mlp/w_up")
    assert spec == P()
    assert any("mlp/w_up" in r.message for r in caplog.records)
    with pytest.raises(ValueError, match="mlp/w_up"):
        shd._divisible(P("model"), (15,), _M(), path="mlp/w_up",
                       strict=True)
    # anonymous (activation-constraint) calls stay silent
    with caplog.at_level(logging.WARNING, "repro.distributed.sharding"):
        n0 = len(caplog.records)
        assert shd._divisible(P("model"), (15,), _M()) == P()
    assert len(caplog.records) == n0
    del mesh


# ---- 1-device mesh (fast, in-process) -----------------------------------------


@pytest.fixture(scope="module")
def tiny():
    return get_config("llama2-60m").smoke()


@pytest.fixture(scope="module")
def tiny_params(tiny):
    return registry.init_params(tiny, jax.random.PRNGKey(0))


def _scfg(fmt="nvfp4", **kw):
    kw.setdefault("eos_id", NO_EOS)
    return ServeConfig(batch_size=2, max_len=64, kv_cache_format=fmt,
                       page_size=16, **kw)


def test_make_serve_mesh_default_and_errors():
    mesh = shd.make_serve_mesh(None)
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == {"model": 1}
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        shd.make_serve_mesh("tp=8")


def test_spec_for_packed_replicated_on_one_device(tiny, tiny_params):
    """On the 1-device mesh every packed leaf canonicalizes to P() —
    placement is the identity, the degenerate case of the same rules."""
    from repro.core.quantize import pack_quantize
    mesh = shd.make_serve_mesh(None)
    pw = pack_quantize(jnp.ones((64, 32), jnp.float32), axis=-2)
    sh = shd.spec_for_packed("layers/attn/wq", pw, mesh)
    assert sh == {"packed": P(), "scales": P(), "tscale": P()}


@pytest.mark.parametrize("fmt", FMTS)
def test_explicit_mesh_engine_token_identical(tiny, tiny_params, fmt):
    """ContinuousEngine under an explicit 1-device mesh spec is BIT-
    identical (no margin gate) to the default engine: same code path."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, tiny.vocab_size, 8) for _ in range(2)]
    base = ContinuousEngine(tiny, tiny_params, _scfg(fmt))
    out_b = base.generate(prompts, max_new=8)
    meshed = ContinuousEngine(tiny, tiny_params,
                              dataclasses.replace(_scfg(fmt), mesh="tp=1"))
    out_m = meshed.generate(prompts, max_new=8)
    for a, b in zip(out_m, out_b):
        np.testing.assert_array_equal(a, b)
    assert meshed.prefill_compiles == 1 and meshed.decode_compiles == 1


def test_lockstep_mesh_engine_token_identical(tiny, tiny_params):
    """Same for the lockstep Engine, with the mesh passed explicitly."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, tiny.vocab_size, 8) for _ in range(2)]
    out_b = Engine(tiny, tiny_params, _scfg()).generate(prompts, max_new=8)
    eng = Engine(tiny, tiny_params, _scfg(),
                 mesh=shd.make_serve_mesh("tp=1"))
    out_m = eng.generate(prompts, max_new=8)
    for a, b in zip(out_m, out_b):
        np.testing.assert_array_equal(a, b)


def test_mesh_engine_no_recompile_across_runs(tiny, tiny_params):
    """Jit-cache guards under the mesh: slot reuse, a second run, and the
    stable-sharding carry/token annotations keep all three programs at
    ONE compilation each."""
    scfg = dataclasses.replace(_scfg("nvfp4"), mesh="tp=1")
    rng = np.random.default_rng(2)
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    mk = lambda rid, n, arr=0: Request(
        rid, rng.integers(0, tiny.vocab_size, n), max_new=6, arrival=arr)
    eng.run([mk(0, 8), mk(1, 6), mk(2, 5, arr=1)])   # queued -> freed slot
    eng.run([mk(3, 7), mk(4, 4)])                    # second trace
    assert eng.prefill_compiles == 1
    assert eng.decode_compiles == 1
    assert eng.prefill_suffix_compiles <= 1


# ---- real TP (subprocess, forced host devices) --------------------------------


_TP_ENGINE = """
    import dataclasses
    import jax, numpy as np
    from repro.configs import get_config
    from repro.models import registry
    from repro.serve import ContinuousEngine, ServeConfig

    cfg = get_config("llama2-60m").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_size=2, max_len=64, kv_cache_format="nvfp4",
                       page_size=16, eos_id=-1)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8) for _ in range(2)]

    ref = ContinuousEngine(cfg, params, scfg).generate(prompts, max_new=8)
    for tp in (2, 4):
        eng = ContinuousEngine(cfg, params,
                               dataclasses.replace(scfg, mesh=f"tp={tp}"))
        out = eng.generate(prompts, max_new=8)
        for a, b in zip(out, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert eng.prefill_compiles == 1 and eng.decode_compiles == 1, tp
        print(f"tp{tp} engine OK")
"""


@pytest.mark.slow
def test_tp_engine_token_identical_multidevice(run_multidev):
    """TP=2 and TP=4 ContinuousEngine: EXACTLY the 1-device token streams
    (TP reduction orders are fixed per device count by the psum tree; the
    quantize-once packed weights make the local GEMMs bit-stable), with
    the one-compile-per-program guarantee intact."""
    r = run_multidev(_TP_ENGINE)
    assert "tp2 engine OK" in r.stdout
    assert "tp4 engine OK" in r.stdout


_TP_MATMUL = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import fqt
    from repro.core.quantize import pack_quantize
    from repro.distributed.compression import allgather_packed
    from repro.distributed.sharding import make_serve_mesh
    from repro.kernels.fp4_matmul import tp_fp4_matmul

    cfg = fqt.qaf_config()
    K, N = 64, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (4, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32) * 0.2
    pw = pack_quantize(w, axis=-2)

    # 1-device reference: quantize-a once, dequantized packed GEMM
    qx = fqt._maybe_q(x, fqt._if_divisible(cfg.fwd_a, K), axis=-1,
                      seed=jnp.zeros((), jnp.uint32), site=0)
    ref = jnp.matmul(qx, pw.dequant(),
                     preferred_element_type=jnp.float32).astype(x.dtype)

    mesh = make_serve_mesh("tp=2")
    col = tp_fp4_matmul(x, pw, cfg=cfg, mesh=mesh, parallel="column")
    np.testing.assert_array_equal(np.asarray(col), np.asarray(ref))
    row = tp_fp4_matmul(x, pw, cfg=cfg, mesh=mesh, parallel="row")
    np.testing.assert_allclose(np.asarray(row), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("tp matmul OK")

    # FSDP-style gather of the PACKED wire format: bitwise column result
    mesh2 = make_serve_mesh("dp=2,tp=2")
    both = tp_fp4_matmul(x, pw, cfg=cfg, mesh=mesh2, parallel="column",
                         gather_axis="data")
    np.testing.assert_array_equal(np.asarray(both), np.asarray(ref))
    assert pw.wire_nbytes() == K * (N // 2) + (K // 16) * N
    print("packed gather OK")
"""


@pytest.mark.slow
def test_tp_matmul_collectives_multidevice(run_multidev):
    """The explicit Megatron decomposition of the packed GEMM: column-
    parallel bitwise == 1-device (activation quantized once, globally),
    row-parallel allclose (psum reorder only), and the ~4.5 bits/param
    packed all-gather reconstructs the exact weight shards."""
    r = run_multidev(_TP_MATMUL)
    assert "tp matmul OK" in r.stdout
    assert "packed gather OK" in r.stdout
