"""Flash-attention (custom_vjp) vs dense-softmax reference: forward and
gradients, causal + sliding-window, plus the counter-bits RNG quality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats
from repro.models.layers import _attn_dense, _attn_flash, attention_core


def _qkv(B=2, S=128, KVH=2, G=2, D=16):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, KVH, G, D),
                          jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, D), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [None, 48])
def test_flash_forward_matches_dense(window):
    q, k, v = _qkv()
    pos = jnp.arange(q.shape[1], dtype=jnp.int32)
    ref = _attn_dense(q, k, v, pos, pos, True, window)
    out = _attn_flash(q, k, v, pos, pos, True, window, 32, 32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [None, 48])
def test_flash_backward_matches_dense(window):
    """The recompute backward (flash custom_vjp) == autodiff of dense."""
    q, k, v = _qkv()
    pos = jnp.arange(q.shape[1], dtype=jnp.int32)
    c = jax.random.normal(jax.random.PRNGKey(3), q.shape, jnp.float32)

    ref_g = jax.grad(lambda *a: jnp.sum(
        _attn_dense(*a, pos, pos, True, window) * c), argnums=(0, 1, 2))(
        q, k, v)
    new_g = jax.grad(lambda *a: jnp.sum(
        _attn_flash(*a, pos, pos, True, window, 32, 32) * c),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(ref_g, new_g):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-4, atol=1e-4)


def test_attention_core_decode_kvlen_mask():
    """Decode against a partially-filled cache must ignore unwritten slots."""
    q, k, v = _qkv(B=1, S=1)
    kc = jnp.zeros((1, 64, 2, 16), jnp.float32).at[:, :8].set(
        jax.random.normal(jax.random.PRNGKey(4), (1, 8, 2, 16)))
    vc = jnp.zeros_like(kc).at[:, :8].set(
        jax.random.normal(jax.random.PRNGKey(5), (1, 8, 2, 16)))
    qpos = jnp.asarray([7], jnp.int32)
    kpos = jnp.arange(64, dtype=jnp.int32)
    out_full = attention_core(q.reshape(1, 1, 4, 16), kc, vc, qpos=qpos,
                              kpos=kpos, kv_len=jnp.asarray(8))
    out_trunc = attention_core(q.reshape(1, 1, 4, 16), kc[:, :8], vc[:, :8],
                               qpos=qpos, kpos=kpos[:8])
    np.testing.assert_allclose(np.asarray(out_full), np.asarray(out_trunc),
                               rtol=1e-5, atol=1e-5)


def test_counter_bits_uniformity():
    """splitmix32 counter bits: mean/var of the induced uniforms and lag-1
    correlation good enough for SR (we need 24 decorrelated bits)."""
    bits = formats.counter_bits(jnp.uint32(1234), (1 << 16,))
    u = np.asarray(formats.uniform_from_bits(bits))
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.var() - 1 / 12) < 0.005
    lag1 = np.corrcoef(u[:-1], u[1:])[0, 1]
    assert abs(lag1) < 0.02
    # different seeds decorrelate
    u2 = np.asarray(formats.uniform_from_bits(
        formats.counter_bits(jnp.uint32(1235), (1 << 16,))))
    assert abs(np.corrcoef(u, u2)[0, 1]) < 0.02


def test_counter_bits_deterministic():
    a = formats.counter_bits(jnp.uint32(7), (64, 32))
    b = formats.counter_bits(jnp.uint32(7), (64, 32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
