"""Block-quantization (NVFP4/MXFP4) tests."""
import numpy as np
import pytest
import jax
import ml_dtypes
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # offline container: deterministic shim
    from _hyp import given, settings, strategies as st

from repro.core import formats
from repro.core.quantize import (MXFP4, NVFP4, BlockQuantSpec, block_quantize,
                                 fake_quant, pack_e2m1, unpack_e2m1)


def test_roundtrip_shapes():
    x = jnp.ones((4, 64), jnp.float32)
    qt = block_quantize(x, NVFP4, axis=-1)
    assert qt.codes.shape == (4, 64)
    assert qt.scales.shape == (4, 4)
    assert qt.dequant().shape == (4, 64)


@pytest.mark.parametrize("spec", [NVFP4, MXFP4,
                                  BlockQuantSpec(scale_fmt="e3m4", block=8),
                                  BlockQuantSpec(two_level=False)])
@pytest.mark.parametrize("axis", [0, 1, -1])
def test_reconstruction_error_bound(spec, axis):
    """Relative error per block bounded by FP4 resolution (~ half max ulp of
    the block: ulp(6)=2 => 1/6 of amax, plus scale rounding)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32)) * 3.0
    deq = np.asarray(fake_quant(x, spec, axis=axis))
    xb = np.asarray(x)
    err = np.abs(deq - xb)
    # per-element bound: half the largest code gap * scale; scale <= amax/4
    # (post-rounding) => err <= amax/4. Use a loose but meaningful bound.
    assert err.max() <= np.abs(xb).max() * 0.30


def test_exact_on_representable():
    """Values that are exactly scale*grid reconstruct exactly."""
    scales = 2.0 ** np.arange(-3, 3)
    grid = np.array([0, .5, 1, 1.5, 2, 3, 4, 6])
    x = (scales[:, None] * grid[None, :]).astype(np.float32)  # (6, 8)
    x = np.tile(x, (1, 2))  # block 16
    deq = np.asarray(fake_quant(jnp.asarray(x), NVFP4, axis=-1))
    np.testing.assert_allclose(deq, x, rtol=0, atol=0)


def test_codes_on_grid():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((16, 32)).astype(np.float32)) * 100
    qt = block_quantize(x, NVFP4)
    assert formats.snap_distance(np.asarray(qt.codes), formats.E2M1).max() == 0


def test_scales_on_scale_grid():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    qt = block_quantize(x, NVFP4)
    assert formats.snap_distance(np.asarray(qt.scales), formats.E4M3).max() == 0
    # MXFP4 scales are powers of two
    qt2 = block_quantize(x, MXFP4)
    log2s = np.log2(np.asarray(qt2.scales, np.float64))
    np.testing.assert_allclose(log2s, np.round(log2s), atol=0)


def test_two_level_scale_prevents_gross_clipping():
    """Without two_level, amax=1e6 >> 448*6 would clip the E4M3 block scale
    (scale saturates at 448 => values reconstruct at <= 448*6 = 2688, a 370x
    error).  With the per-tensor pow2 scale the error is bounded by block-scale
    RtN rounding (<= ulp/2 of E4M3 ~ 6%) plus code clipping."""
    x = jnp.full((1, 16), 1e6, jnp.float32)
    deq = np.asarray(fake_quant(x, NVFP4))
    np.testing.assert_allclose(deq, 1e6, rtol=0.07)
    deq_1l = np.asarray(
        fake_quant(x, BlockQuantSpec(two_level=False)))
    assert deq_1l.max() <= 448 * 6  # the failure mode two_level fixes


def test_mxfp4_ocp_scale_rule():
    # amax = 5.0: floor(log2 5)=2 -> scale = 2^(2-2) = 1
    x = jnp.asarray([[5.0] + [0.1] * 31], jnp.float32)
    qt = block_quantize(x, MXFP4)
    assert float(qt.scales[0, 0]) == 1.0


def test_bf16_exactness():
    """Simulation fidelity (DESIGN.md §3): every dequantized NVFP4 value
    (E2M1 code x E4M3 scale x pow2 tensor scale) is exactly representable in
    bf16, so bf16 MXU matmuls on dequantized operands are bit-identical to a
    native FP4 block-scaled GEMM."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32)) * 7.3e4
    deq32 = np.asarray(fake_quant(x, NVFP4))
    roundtrip = deq32.astype(ml_dtypes.bfloat16).astype(np.float32)
    np.testing.assert_array_equal(deq32, roundtrip)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_sr_block_unbiased(seed):
    """Block-quant with SR is unbiased (within clipping): mean over many draws
    converges to x."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, 16)).astype(np.float32))
    spec = NVFP4.with_rounding(stochastic=True)
    draws = []
    for i in range(256):
        draws.append(fake_quant(x, spec, key=jax.random.PRNGKey(i)))
    mean = np.mean(np.stack(draws), axis=0)
    qt = block_quantize(x, spec, key=jax.random.PRNGKey(0))
    # Representable ceiling of the block: 6 * (E4M3-rounded scale) * tscale.
    # When the scale rounds *down*, the block's amax element saturates — the
    # one documented bias source (tail clipping; identical in FP4 hardware).
    ceil = 6.0 * float(qt.scales[0, 0] * qt.tscale)
    clipped = np.abs(np.asarray(x)) > ceil
    scale = float(jnp.max(jnp.abs(x))) / 6.0
    # SR noise per draw is <= one code gap * scale; SE shrinks as 1/sqrt(256)
    np.testing.assert_allclose(mean[~clipped], np.asarray(x)[~clipped],
                               atol=4 * scale / 16 + 1e-4)
    # clipped elements deterministically saturate to sign * ceiling
    np.testing.assert_allclose(
        np.abs(mean[clipped]), np.full(clipped.sum(), ceil), rtol=1e-6)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((8, 32)).astype(np.float32))
    qt = block_quantize(x, NVFP4)
    packed = pack_e2m1(qt.codes)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (8, 16)
    unpacked = unpack_e2m1(packed)
    np.testing.assert_array_equal(np.asarray(unpacked), np.asarray(qt.codes))


def test_zero_block():
    x = jnp.zeros((2, 32), jnp.float32)
    deq = fake_quant(x, NVFP4)
    np.testing.assert_array_equal(np.asarray(deq), 0.0)
    assert np.isfinite(np.asarray(block_quantize(x, NVFP4).scales)).all()


def test_indivisible_block_raises():
    with pytest.raises(ValueError):
        block_quantize(jnp.ones((2, 17)), NVFP4)
