"""fp4lint self-tests: every rule fires on its positive fixture, stays
silent on the clean twin, and is silenced by the pragma; the whole-repo
run is exactly at its checked-in baseline; a deliberately seeded
violation of each rule in a scratch file is caught by the whole-repo
run; and the rounding-policy rule proves no SR spec is constructible
from serve/ or models/ module scope.

Everything here is jax-free (repro.analysis is pure stdlib), so this
file runs even when the accelerator stack is broken.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (DEFAULT_SCAN_DIRS, RULES, all_rule_names,
                            baseline_diff, lint_paths, lint_source,
                            load_baseline, render_baseline)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO_ROOT, "tools", "lint_baseline.txt")


def rules_of(findings):
    return {f.rule for f in findings}


def run(src, path):
    return lint_source(textwrap.dedent(src), path)


# ---- fixtures: (rule, firing source, firing path, clean source, clean path)


FIXTURES = {
    "rounding-policy": dict(
        firing="spec = BlockQuantSpec(stochastic=True)\n",
        firing_path="src/repro/serve/x.py",
        clean="spec = BlockQuantSpec(stochastic=True)\n",
        clean_path="src/repro/train/x.py",       # backward path: allowed
    ),
    "prng-reuse": dict(
        firing="""
        def f(seed, shape):
            key = jax.random.PRNGKey(seed)
            a = jax.random.normal(key, shape)
            b = jax.random.uniform(key, shape)
            return a, b
        """,
        firing_path="src/repro/x.py",
        clean="""
        def f(seed, shape):
            key = jax.random.PRNGKey(seed)
            ka, kb = jax.random.split(key)
            a = jax.random.normal(ka, shape)
            b = jax.random.uniform(kb, shape)
            return a, b
        """,
        clean_path="src/repro/x.py",
    ),
    "spec-canonical": dict(
        firing='spec = P("model", None)\n',
        firing_path="src/repro/x.py",
        clean='spec = P("model")\n',
        clean_path="src/repro/x.py",
    ),
    "trace-hazard": dict(
        firing="""
        @jax.jit
        def f(x):
            return x * float(x.mean())
        """,
        firing_path="src/repro/x.py",
        clean="""
        def f(x):
            return x * float(x.mean())    # not traced: host code
        """,
        clean_path="src/repro/x.py",
    ),
    "packed-dtype": dict(
        firing="w = qt.packed.astype(jnp.float32)\n",
        firing_path="src/repro/serve/x.py",
        clean="w = qt.packed.astype(jnp.float32)\n",
        clean_path="src/repro/core/quantize.py",  # sanctioned dequant site
    ),
    "obs-in-jit": dict(
        firing="""
        @jax.jit
        def decode_step(x, tracer):
            tracer.counter("decode_steps")
            return x
        """,
        firing_path="src/repro/serve/x.py",
        clean="""
        def host_tick(x, tracer):
            tracer.counter("decode_steps")   # host loop: emit freely
            return decode_step(x)
        """,
        clean_path="src/repro/serve/x.py",
    ),
}


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_fires_on_positive(rule):
    fx = FIXTURES[rule]
    found = run(fx["firing"], fx["firing_path"])
    assert rule in rules_of(found), (rule, found)


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_silent_on_clean_twin(rule):
    fx = FIXTURES[rule]
    found = run(fx["clean"], fx["clean_path"])
    assert rule not in rules_of(found), (rule, found)


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_silenced_by_pragma(rule):
    fx = FIXTURES[rule]
    src = textwrap.dedent(fx["firing"])
    # annotate every line: same-line pragmas silence wherever it fired
    src = "".join(f"{ln}  # fp4lint: disable={rule}\n" if ln.strip() else "\n"
                  for ln in src.splitlines())
    assert rule not in rules_of(lint_source(src, fx["firing_path"]))


def test_every_shipped_rule_has_a_fixture_and_a_docstring_example():
    assert sorted(FIXTURES) == all_rule_names()
    for name, rule in RULES.items():
        doc = rule.check.__self__.__doc__ or rule.__doc__
        assert doc and "FIRES" in doc and "CLEAN" in doc, name


# ---- pragma mechanics ---------------------------------------------------------


def test_standalone_pragma_covers_next_line():
    src = ('# fp4lint: disable=spec-canonical\n'
           'spec = P("model", None)\n')
    assert lint_source(src, "src/repro/x.py") == []


def test_trailing_pragma_covers_only_its_own_line():
    src = ('a = P("model", None)  # fp4lint: disable=spec-canonical\n'
           'b = P("model", None)\n')
    found = lint_source(src, "src/repro/x.py")
    assert [f.line for f in found] == [2]


def test_bare_disable_silences_all_rules():
    src = 'w = qt.packed.astype(jnp.float32)  # fp4lint: disable\n'
    assert lint_source(src, "src/repro/serve/x.py") == []


def test_pragma_for_other_rule_does_not_silence():
    src = 'spec = P("model", None)  # fp4lint: disable=packed-dtype\n'
    assert rules_of(lint_source(src, "src/repro/x.py")) == {"spec-canonical"}


# ---- rule-specific behavior ---------------------------------------------------


def test_rounding_policy_with_rounding_in_models():
    found = run("sr = NVFP4.with_rounding(True)\n", "src/repro/models/m.py")
    assert rules_of(found) == {"rounding-policy"}


def test_rounding_policy_kernel_decode_scopes():
    # decode, draft and verify functions are all forward serving paths:
    # an SR draft desyncs from the RtN verify, an SR verify breaks
    # bit-exactness vs sequential decode
    for fn in ("decode_read", "draft_propose", "verify_k_read",
               "spec_verify"):
        fire = f"""
        def {fn}(pool):
            return dequant(pool, NVFP4.with_rounding(True))
        """
        assert rules_of(run(fire, "src/repro/kernels/k.py")) \
            == {"rounding-policy"}, fn
    ok = """
    def backward_quant(g):
        return quant(g, NVFP4.with_rounding(True))
    """
    assert rules_of(run(ok, "src/repro/kernels/k.py")) == set()


def test_rounding_policy_pack_quantize_anywhere():
    src = "qt = pack_quantize(w, BlockQuantSpec(stochastic=True))\n"
    found = run(src, "src/repro/train/x.py")     # even on the train side
    assert rules_of(found) == {"rounding-policy"}


def test_rounding_policy_not_constructible_from_serve_or_models():
    """The static proof the issue asks for: (a) today neither serve/ nor
    models/ constructs an SR spec anywhere (module or function scope);
    (b) for EVERY file there, introducing one would fire the rule."""
    serve_models = [p for p in _scan_files()
                    if "/serve/" in p or "/models/" in p]
    assert serve_models, "scan set lost serve//models/"
    for path in serve_models:
        rel = os.path.relpath(path, REPO_ROOT)
        with open(path, encoding="utf-8") as f:
            src = f.read()
        assert "rounding-policy" not in rules_of(lint_source(src, rel)), rel
        seeded = src + "\n_viol = BlockQuantSpec(stochastic=True)\n"
        assert "rounding-policy" in rules_of(lint_source(seeded, rel)), rel


def test_prng_literal_scoping():
    src = "key = jax.random.PRNGKey(0)\n"
    assert rules_of(run(src, "src/repro/x.py")) == {"prng-reuse"}
    for exempt in ("tests/test_x.py", "src/repro/configs/x.py",
                   "benchmarks/x.py", "tools/x.py"):
        assert rules_of(run(src, exempt)) == set(), exempt


def test_prng_reuse_branches_do_not_cross_flag():
    src = """
    def f(key, c, shape):
        if c:
            a = jax.random.normal(key, shape)
        else:
            a = jax.random.uniform(key, shape)   # exclusive: not reuse
        return a
    """
    assert rules_of(run(src, "src/repro/x.py")) == set()


def test_prng_reuse_single_statement_double_sample():
    src = """
    def f(key, shape):
        return {"a": jax.random.normal(key, shape),
                "b": jax.random.normal(key, shape)}
    """
    found = run(src, "src/repro/x.py")
    assert [f.rule for f in found] == ["prng-reuse"]   # exactly once


def test_spec_canonical_all_replicated_and_interior_none():
    assert rules_of(run("s = P(None, None)\n", "src/repro/x.py")) \
        == {"spec-canonical"}
    # interior None is fine — only TRAILING Nones are non-canonical
    assert rules_of(run('s = P(None, "model")\n', "src/repro/x.py")) == set()
    assert rules_of(run("s = PartitionSpec()\n", "src/repro/x.py")) == set()


def test_trace_hazard_call_site_and_raise_exemption():
    src = """
    def _impl(self, x):
        return x * float(x.mean())
    step = jax.jit(_impl)
    """
    assert rules_of(run(src, "src/repro/x.py")) == {"trace-hazard"}
    ok = """
    @jax.jit
    def f(x):
        if x.shape[0] != 4:
            raise ValueError(f"bad leading dim {x.shape[0]} for {x}")
        n = float(x.shape[0])            # static metadata: exempt
        return x * n
    """
    assert rules_of(run(ok, "src/repro/x.py")) == set()


def test_trace_hazard_item_and_asarray_in_pallas_body():
    src = """
    def kernel(x_ref, o_ref):
        o_ref[...] = np.asarray(x_ref[...]).sum() + x_ref[0].item()
    out = pl.pallas_call(kernel, out_shape=shape)(x)
    """
    found = run(src, "src/repro/x.py")
    assert [f.rule for f in found] == ["trace-hazard", "trace-hazard"]


def test_packed_dtype_scales_and_storage_cast():
    assert rules_of(run("s = scales.astype(jnp.bfloat16)\n",
                        "src/repro/distributed/x.py")) == {"packed-dtype"}
    # storage-width cast stays clean; kernels/ is a sanctioned site
    assert rules_of(run("n = qt.packed.astype(jnp.uint8)\n",
                        "src/repro/serve/x.py")) == set()
    assert rules_of(run("w = codes.astype(jnp.float32)\n",
                        "src/repro/kernels/k.py")) == set()


# ---- whole-repo run + baseline ------------------------------------------------


def _scan_files():
    from repro.analysis.engine import iter_py_files
    return iter_py_files(DEFAULT_SCAN_DIRS, REPO_ROOT)


def test_whole_repo_exactly_at_baseline():
    findings, stats = lint_paths(root=REPO_ROOT)
    new, stale = baseline_diff(findings, load_baseline(BASELINE))
    assert new == [], "non-baselined findings:\n" + "\n".join(
        f.render() for f in new)
    assert stale == [], f"stale baseline entries: {stale}"
    assert stats.files_scanned > 80      # the scan set is the real repo
    assert stats.parse_errors == 0


def test_empty_baseline_for_prng_and_spec_rules():
    """Issue acceptance: prng-reuse and spec-canonical true positives were
    FIXED, not grandfathered (and so was everything else, in fact)."""
    entries = load_baseline(BASELINE)
    for rule in ("prng-reuse", "spec-canonical"):
        assert not any(f":{rule}:" in e for e in entries), entries


def test_seeded_violations_caught_by_whole_repo_run(tmp_path):
    """One scratch file violating all six rules, dropped into the scan
    tree: the whole-repo run must catch every one of them."""
    scratch = os.path.join(REPO_ROOT, "src", "repro", "serve",
                           "_lint_seed_scratch.py")
    src = textwrap.dedent("""
        spec = BlockQuantSpec(stochastic=True)
        key = jax.random.PRNGKey(0)
        pspec = P("model", None)
        w = qt.packed.astype(jnp.float32)

        @jax.jit
        def f(x):
            return x * float(x.mean())

        @jax.jit
        def g(x, tracer):
            tracer.counter("oops")
            return x
        """)
    try:
        with open(scratch, "w", encoding="utf-8") as f:
            f.write(src)
        findings, _ = lint_paths(root=REPO_ROOT)
        hit = {f.rule for f in findings
               if f.path == "src/repro/serve/_lint_seed_scratch.py"}
        assert hit == set(all_rule_names()), hit
        new, _ = baseline_diff(findings, load_baseline(BASELINE))
        assert len(new) >= 6             # none of them baselined away
    finally:
        os.unlink(scratch)


# ---- baseline machinery -------------------------------------------------------


def test_baseline_keys_are_line_number_independent():
    src_a = 'spec = P("model", None)\n'
    src_b = "\n\n# moved down by unrelated edits\n" + src_a
    fa = lint_source(src_a, "src/repro/x.py")
    fb = lint_source(src_b, "src/repro/x.py")
    assert fa[0].key() == fb[0].key()
    assert fa[0].line != fb[0].line


def test_baseline_diff_both_directions():
    found = lint_source('s = P("a", None)\n', "src/repro/x.py")
    new, stale = baseline_diff(found, [])
    assert new == found and stale == []
    new, stale = baseline_diff(found, [found[0].key(), "ghost:rule:line"])
    assert new == [] and stale == ["ghost:rule:line"]
    # duplicates are a multiset: one baseline entry covers one finding
    new, stale = baseline_diff(found + found, [found[0].key()])
    assert len(new) == 1 and stale == []


def test_render_baseline_deterministic():
    findings, _ = lint_paths(["src"], root=REPO_ROOT)
    assert render_baseline(findings) == render_baseline(list(findings))
    assert render_baseline(reversed(findings)) == render_baseline(findings)


# ---- the CLI ------------------------------------------------------------------


def _cli(*args, cwd=REPO_ROOT):
    return subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "tools", "lint.py"),
         *args], capture_output=True, text=True, cwd=cwd)


def test_cli_green_on_current_repo():
    r = _cli("--stats")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "fp4lint:" in r.stdout


def test_cli_fails_on_non_baselined_finding_and_stale_entry(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text('spec = P("model", None)\n')
    r = _cli(str(bad))
    assert r.returncode == 1
    assert "spec-canonical" in r.stdout and 'P("model", None)' in r.stdout
    stale = tmp_path / "stale_baseline.txt"
    # under the scanned prefix, so the partial scan judges it; entries for
    # unscanned trees are exempt from staleness (the scan can't see them)
    stale.write_text("src/repro/ghost.py:spec-canonical:x = P(None, None)\n"
                     "elsewhere/ghost.py:spec-canonical:x = P(None, None)\n")
    r = _cli("src", "--baseline", str(stale))
    assert r.returncode == 1
    assert r.stdout.count("stale baseline entry") == 1   # src/ one only


def test_cli_update_baseline_deterministic(tmp_path):
    bl = tmp_path / "bl.txt"
    r1 = _cli("--update-baseline", "--baseline", str(bl))
    first = bl.read_text()
    r2 = _cli("--update-baseline", "--baseline", str(bl))
    assert r1.returncode == r2.returncode == 0
    assert bl.read_text() == first
    # and the current repo state writes an EMPTY baseline (header only)
    assert all(ln.startswith("#") or not ln.strip()
               for ln in first.splitlines())
