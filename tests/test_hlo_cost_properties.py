"""Property tests for the trip-count-aware HLO cost pass (launch/hlo_cost)
— the §Roofline numbers are only as good as this parser."""
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:        # offline container: deterministic shim
    from _hyp import given, settings, strategies as st

from repro.launch import hlo_cost


def _cost(fn, *args):
    return hlo_cost.analyze(jax.jit(fn).lower(*args).compile().as_text())


@settings(max_examples=8, deadline=None)
@given(m=st.sampled_from([32, 64, 128]), k=st.sampled_from([32, 128]),
       n=st.sampled_from([32, 64]))
def test_dot_flops_exact(m, k, n):
    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    c = _cost(lambda a, b: a @ b, a, b)
    assert c.flops == pytest.approx(2 * m * k * n, rel=0.01)


@settings(max_examples=6, deadline=None)
@given(trips=st.sampled_from([2, 5, 16, 40]))
def test_while_trip_multiplication(trips):
    M = 64
    w = jax.ShapeDtypeStruct((trips, M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def f(x, w):
        return jax.lax.scan(lambda h, wi: (jnp.dot(h, wi), None), x, w)[0]

    c = _cost(f, x, w)
    assert c.flops == pytest.approx(trips * 2 * M ** 3, rel=0.01)


def test_nested_scan_multiplies():
    """scan-of-scan: flops must scale by BOTH trip counts."""
    M, outer, inner = 32, 3, 4
    w = jax.ShapeDtypeStruct((outer, inner, M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def f(x, w):
        def outer_body(h, wo):
            h, _ = jax.lax.scan(
                lambda hh, wi: (jnp.dot(hh, wi), None), h, wo)
            return h, None
        return jax.lax.scan(outer_body, x, w)[0]

    c = _cost(f, x, w)
    assert c.flops == pytest.approx(outer * inner * 2 * M ** 3, rel=0.01)


def test_bytes_min_le_bytes_and_monotone():
    M = 64
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    small = _cost(lambda a: jnp.tanh(a @ a), x)
    big = _cost(lambda a: jnp.tanh(a @ a) @ a + a, x)
    assert 0 <= small.bytes_min <= small.bytes
    assert big.flops > small.flops
    assert big.bytes >= small.bytes


def test_shape_bytes_dtypes():
    assert hlo_cost._shape_bytes("bf16[4,8]") == 64
    assert hlo_cost._shape_bytes("f32[10]{0}") == 40
    assert hlo_cost._shape_bytes("u4[16]") == 8
    assert hlo_cost._shape_bytes("(f32[2,2], bf16[4])") == 24
    assert hlo_cost._shape_bytes("pred[]") == 1    # scalar


def test_collectives_counted_by_kind():
    """A psum under jit with sharding produces an all-reduce whose bytes
    land in the right bucket (uses a tiny 1-device mesh: the collective
    may be optimized away — so parse a synthetic module instead)."""
    hlo = """
HloModule m, entry_computation_layout={()->f32[8]}

ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%p), to_apply=%add
  ROOT %ag = f32[16]{0} all-gather(%ar), dimensions={0}
}
"""
    c = hlo_cost.analyze(hlo)
    assert c.coll["all-reduce"] == 32
    assert c.coll["all-gather"] == 64
