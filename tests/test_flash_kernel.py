"""Pallas flash-attention kernel vs the dense-softmax oracle: shape/dtype/
mask sweeps in interpret mode (per-kernel allclose requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention
from repro.models.layers import attention_core


def _qkv(B, S, H, KVH, D, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KVH, D), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KVH, D), dtype)
    return q, k, v


@pytest.mark.parametrize("shape", [
    (1, 64, 2, 2, 16),      # MHA
    (2, 128, 4, 2, 32),     # GQA group 2
    (1, 128, 8, 2, 64),     # GQA group 4
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (True, 48), (False, None)])
def test_flash_kernel_matches_oracle(shape, causal, window):
    B, S, H, KVH, D = shape
    q, k, v = _qkv(B, S, H, KVH, D, jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = attention_core(q, k, v, qpos=pos, kpos=pos, causal=causal,
                         window=window, chunk=4096)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_kv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_kernel_bf16():
    q, k, v = _qkv(1, 64, 2, 2, 32, jnp.bfloat16)
    pos = jnp.arange(64, dtype=jnp.int32)
    ref = attention_core(q, k, v, qpos=pos, kpos=pos, chunk=4096)
    out = flash_attention(q, k, v, block_q=32, block_kv=32, interpret=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_kernel_block_shapes():
    """Different VMEM tilings give identical results."""
    q, k, v = _qkv(1, 128, 2, 2, 16, jnp.float32)
    outs = [flash_attention(q, k, v, block_q=bq, block_kv=bk,
                            interpret=True)
            for bq, bk in ((32, 32), (64, 32), (32, 64), (128, 128))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-6, atol=1e-6)


def test_flash_kernel_rejects_bad_gqa():
    q, k, v = _qkv(1, 64, 3, 2, 16, jnp.float32)
    with pytest.raises(ValueError, match="GQA"):
        flash_attention(q, k, v, interpret=True)
