"""Multi-tenant traffic harness: chunked prefill + request lifecycle (PR 8).

Layers of evidence:
  * EXACTNESS: chunked prefill is quantize-then-attend — every chunk
    writes its RtN pages first and attends THROUGH the paged cache, so
    the greedy token streams are BIT-identical to unchunked admission
    for every chunk size (straddling page boundaries) and every KV
    format nvfp4/fp8/bf16 (strict equality, no margin gate);
  * chunk budget: no tick ever feeds more than ``prefill_chunk`` prompt
    tokens into a slot (``Scheduler.prefill_log`` is the evidence), and
    the jit caches stay at EXACTLY one compile per program (the fourth,
    chunk program included; the plain prefill program is never used);
  * LIFECYCLE: abort/timeout cancels at EVERY stage — queued, mid-
    chunked-prefill, decoding, after completion (a no-op) — leak
    nothing: page/slot refcount conservation holds after every tick, no
    live row aliases a page or points at TRASH early, and a slot reused
    after a cancel produces the same stream as a fresh admission;
  * prefix-cache persistence: with ``prefix_cache=True`` the scheduler
    (pool + radix cache + device pages) survives across ``run()``
    traces — a warm rerun is bit-identical to the first trace and to a
    genuinely cold fresh engine;
  * the workload generator end-to-end: a seeded two-tenant trace with
    aborts/timeouts runs to completion with every request accounted for
    exactly once and simulated-clock metrics that reconcile with the
    scheduler's own counters.
"""
import dataclasses

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp import given, settings, strategies as st

from repro.configs import get_config
from repro.models import registry
from repro.models.layers import TRASH_PAGE
from repro.serve import (ContinuousEngine, Request, Scheduler, ServeConfig,
                         TenantSpec, WorkloadConfig, as_requests,
                         generate_workload)

FMTS = ("nvfp4", "fp8", "bf16")
NO_EOS = -1
# chunk sizes vs the 16-token page: mid-page, exactly one page, page+1
# (every chunk boundary crosses a page boundary), and mid-second-page
CHUNKS = (5, 16, 17, 31)
PROMPT_LENS = (37, 12, 33)      # straddle 2 pages / sub-page / straddle 2

# module-level lazy singletons instead of fixtures: the hypothesis sweep
# below cannot take function-scoped pytest fixtures as arguments
_STATE = {}


def _tiny():
    if "cfg" not in _STATE:
        _STATE["cfg"] = get_config("llama2-60m").smoke()
        _STATE["params"] = registry.init_params(_STATE["cfg"],
                                                jax.random.PRNGKey(0))
    return _STATE["cfg"], _STATE["params"]


def _scfg(fmt, **kw):
    return ServeConfig(batch_size=2, max_len=96, eos_id=NO_EOS,
                       kv_cache_format=fmt, page_size=16, decode_chunk=4,
                       **kw)


def _requests(cfg):
    rng = np.random.default_rng(7)
    return [Request(i, rng.integers(0, cfg.vocab_size, n), max_new=8)
            for i, n in enumerate(PROMPT_LENS)]


_BASELINE = {}      # fmt -> {rid: tokens}: UNCHUNKED suffix-path reference


def _baseline(fmt):
    if fmt not in _BASELINE:
        cfg, params = _tiny()
        # prefix_cache=True routes every admission through the quantize-
        # then-attend suffix program — the exactness-preserving baseline
        eng = ContinuousEngine(cfg, params, _scfg(fmt, prefix_cache=True))
        _BASELINE[fmt] = eng.run(_requests(cfg))
    return _BASELINE[fmt]


def _assert_chunk_budget(log, C):
    """prefill_log evidence: <= C tokens per slot per tick, at most one
    chunk per (tick, slot), and every prompt fully streamed.  Pass one
    trace's slice of the log — ticks restart at 0 every ``run()``."""
    seen = set()
    fed = {}
    for tick, slot, rid, clen in log:
        assert 1 <= clen <= C, (tick, slot, rid, clen)
        assert (tick, slot) not in seen, "two chunks for one slot in a tick"
        seen.add((tick, slot))
        fed[rid] = fed.get(rid, 0) + clen
    return fed


# ---- exactness: chunked == unchunked, every chunk size x format ---------------


@settings(max_examples=4, deadline=None)
@given(C=st.sampled_from(CHUNKS))
def _sweep_chunked_exactness(fmt, C):
    """Property body for the fmt x chunk-size sweep (called by the
    parametrized test below: the hypothesis wrapper hides its signature
    from pytest, so fmt rides in as a plain positional argument)."""
    cfg, params = _tiny()
    want = _baseline(fmt)
    eng = ContinuousEngine(cfg, params, _scfg(fmt, prefill_chunk=C))
    res = eng.run(_requests(cfg))
    assert set(res) == set(want)
    for rid in sorted(want):
        np.testing.assert_array_equal(
            res[rid], want[rid],
            err_msg=f"rid {rid} diverged at chunk={C} fmt={fmt}")
    # the four-program contract: exactly one compile each, and the plain
    # prefill-into-slot program is never traced in chunked mode
    assert eng.prefill_compiles == 0
    assert eng.prefill_suffix_compiles == 1
    assert eng.chunk_compiles == 1        # every CHUNKS value < max plen
    assert eng.decode_compiles == 1
    fed = _assert_chunk_budget(eng.scheduler.prefill_log, C)
    assert fed == {i: n for i, n in enumerate(PROMPT_LENS)}
    assert eng.scheduler.pool.pages_in_use == 0


@pytest.mark.parametrize("fmt", FMTS)
def test_chunked_prefill_bit_identical(fmt):
    _sweep_chunked_exactness(fmt)


def test_chunk_covering_whole_prompt_skips_chunk_program():
    """C >= every prompt: admission still defers to prefill_work, but the
    single (final) chunk rides the suffix program alone — the chunk
    program never compiles."""
    cfg, params = _tiny()
    eng = ContinuousEngine(cfg, params, _scfg("nvfp4", prefill_chunk=48))
    res = eng.run(_requests(cfg))
    want = _baseline("nvfp4")
    for rid in sorted(want):
        np.testing.assert_array_equal(res[rid], want[rid])
    assert eng.chunk_compiles == 0
    assert eng.prefill_suffix_compiles == 1


def test_chunked_rejects_unsupported_configs():
    cfg, params = _tiny()
    swa = dataclasses.replace(cfg, sliding_window=32)
    with pytest.raises(NotImplementedError, match="prefill_chunk"):
        ContinuousEngine(swa, params, _scfg("nvfp4", prefill_chunk=8))
    with pytest.raises(ValueError, match="out of range"):
        ContinuousEngine(cfg, params, _scfg("nvfp4", prefill_chunk=1000))
    with pytest.raises(ValueError, match="prefill_chunk"):
        Scheduler(n_slots=1, max_len=32, page_size=8, prefill_chunk=0)


# ---- lifecycle: cancellation at EVERY stage conserves pages (host-side) -------


@settings(max_examples=8, deadline=None)
@given(abort_tick=st.integers(min_value=0, max_value=6))
def test_lifecycle_conservation_at_any_stage(abort_tick):
    """One victim aborted at every possible tick of its life — queued
    (tick 0), mid-chunked-prefill (1-2), decoding (3), or after it
    already finished (>= 4, a no-op).  After every tick: pool refcounts
    conserve, no live row aliases a page, and at the end nothing leaks
    and every rid is accounted for exactly once."""
    sched = Scheduler(n_slots=2, max_len=32, page_size=4, prefill_chunk=3)
    usable = sched.total_pages - 1
    sched.submit(Request(0, np.arange(10, dtype=np.int32), max_new=4))
    sched.submit(Request(1, np.arange(9, dtype=np.int32), max_new=4,
                         abort_at=abort_tick))
    sched.submit(Request(2, np.arange(8, dtype=np.int32), max_new=3,
                         arrival=1))
    for tick in range(30):
        sched.expire(tick)
        sched.admit(tick)
        for _, _, _, clen, _ in sched.prefill_work(tick):
            assert clen <= 3
        T = sched.tick_steps(2)
        sched.ensure_capacity(T)
        if T:
            for slot in sched.decoding_slots():
                sched.commit(slot, np.full((T,), 7, np.int32), NO_EOS)
        # conservation + no-aliasing after EVERY tick, not just at the end
        assert sched.pool.free_pages + sched.pool.pages_in_use == usable
        live = []
        for slot in sched.active_slots():
            row = sched._rows[slot]
            pages = [p for p in row.tolist() if p != TRASH_PAGE]
            npg = sched._npages[slot]
            assert (row[:npg] != TRASH_PAGE).all()     # allocated prefix
            assert (row[npg:] == TRASH_PAGE).all()     # nothing beyond it
            live += pages
        assert len(live) == len(set(live))     # no cross/intra-slot alias
        if not sched.has_work():
            break
    assert not sched.has_work()
    assert sched.pool.pages_in_use == 0
    assert set(sched.results) | set(sched.cancelled) == {0, 1, 2}
    assert set(sched.results) & set(sched.cancelled) == set()
    # rid 1 (plen 9, C=3): final chunk tick 2, decodes 2+2 tokens over
    # ticks 2-3 -> finishes during tick 3; aborts from tick 4 on are no-ops
    stage = {0: "queued", 1: "prefill", 2: "prefill", 3: "decode"}
    if abort_tick in stage:
        assert sched.cancelled[1]["reason"] == "abort"
        assert sched.cancelled[1]["stage"] == stage[abort_tick]
        assert 1 not in sched.results
    else:
        assert 1 in sched.results and 1 not in sched.cancelled
    assert sched.cancelled.get(1, {}).get("tokens", np.zeros(0)).size == \
        (2 if abort_tick == 3 else 0)


def test_cancel_unknown_or_finished_rid_is_false():
    sched = Scheduler(n_slots=1, max_len=16, page_size=4)
    sched.submit(Request(0, np.arange(4, dtype=np.int32), max_new=2))
    sched.admit(0)
    sched.commit(0, np.asarray([5, 6]), eos_id=NO_EOS)     # finishes
    assert not sched.cancel(0)         # already finished
    assert not sched.cancel(99)        # never existed
    assert sched.stats["cancelled"] == 0


# ---- lifecycle through the engine ---------------------------------------------


def test_abort_mid_chunked_prefill_engine_no_leak():
    """An abort landing while the victim is mid-chunked-prefill frees its
    pages and never perturbs the surviving slot's stream (strict token
    equality vs a solo trace — the suffix path is exact)."""
    cfg, params = _tiny()
    eng = ContinuousEngine(cfg, params, _scfg("nvfp4", prefill_chunk=8))
    rng = np.random.default_rng(11)
    long_p = rng.integers(0, cfg.vocab_size, 40)   # 5 chunks: prefills 0-4
    other = rng.integers(0, cfg.vocab_size, 12)
    res = eng.run([Request(0, long_p, max_new=8, abort_at=2),
                   Request(1, other, max_new=8)])
    sched = eng.scheduler
    assert set(res) == {1}
    assert sched.cancelled[0]["reason"] == "abort"
    assert sched.cancelled[0]["stage"] == "prefill"
    assert sched.cancelled[0]["tokens"].size == 0      # never decoded
    assert sched.pool.pages_in_use == 0
    solo = eng.run([Request(1, other, max_new=8)])
    np.testing.assert_array_equal(res[1], solo[1])
    assert eng.scheduler is not sched      # no prefix cache: fresh trace
    assert eng.chunk_compiles == 1 and eng.decode_compiles == 1


def test_timeout_mid_decode_records_partial_stream():
    cfg, params = _tiny()
    eng = ContinuousEngine(cfg, params, _scfg("nvfp4", prefill_chunk=16))
    rng = np.random.default_rng(12)
    prompt = rng.integers(0, cfg.vocab_size, 12)
    res = eng.run([Request(0, prompt, max_new=20, timeout=3)])
    sched = eng.scheduler
    assert res == {}
    c = sched.cancelled[0]
    assert c["reason"] == "timeout" and c["stage"] == "decode"
    assert 0 < c["tokens"].size < 20       # died mid-decode, partial tokens
    assert sched.pool.pages_in_use == 0
    ms = eng.metrics.summary()
    assert ms["cancelled"] == 1 and ms["completed"] == 0
    assert ms["ttft_ticks"]["n"] == 1      # first token DID reach the host


def test_slot_reuse_after_cancel_matches_fresh_admission():
    """A slot freed by an abort admits the next queued request the same
    tick; its stream is bit-identical to running that request alone
    (PRNG keyed by rid, pages scrubbed via the release path)."""
    cfg, params = _tiny()
    eng = ContinuousEngine(cfg, params, _scfg("nvfp4", prefill_chunk=16))
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (20, 18, 24)]
    res = eng.run([Request(0, prompts[0], max_new=16, abort_at=2),
                   Request(1, prompts[1], max_new=16),
                   Request(2, prompts[2], max_new=8, arrival=1)])
    sched = eng.scheduler
    assert set(res) == {1, 2} and 0 in sched.cancelled
    assert sched.pool.pages_in_use == 0
    solo = eng.run([Request(2, prompts[2], max_new=8)])
    np.testing.assert_array_equal(res[2], solo[2])


# ---- prefix-cache persistence across run() traces -----------------------------


def test_prefix_cache_persists_across_runs():
    cfg, params = _tiny()
    scfg = _scfg("nvfp4", prefix_cache=True)
    eng = ContinuousEngine(cfg, params, scfg)
    rng = np.random.default_rng(21)
    system = rng.integers(0, cfg.vocab_size, 36)       # 2 full pages + 4
    prompts = [np.concatenate([system,
                               rng.integers(0, cfg.vocab_size, 4 + i)])
               for i in range(3)]
    first = eng.run([Request(i, prompts[i], max_new=6, arrival=i)
                     for i in range(3)])
    sched = eng.scheduler
    hits = sched.prefix_cache.stats["hits"]
    assert hits == 2                       # rids 1, 2 shared rid 0's pages
    second = eng.run([Request(7, prompts[2], max_new=6)])
    assert eng.scheduler is sched          # SAME scheduler across traces
    assert sched.prefix_cache.stats["hits"] == hits + 1    # still warm
    assert set(second) == {7}              # per-trace results were cleared
    # warm rerun == the first trace == a genuinely cold fresh engine
    np.testing.assert_array_equal(second[7], first[2])
    cold = ContinuousEngine(cfg, params, scfg).run(
        [Request(7, prompts[2], max_new=6)])
    np.testing.assert_array_equal(second[7], cold[7])
    # both traces rode the same compiled programs
    assert eng.prefill_suffix_compiles == 1 and eng.decode_compiles == 1
    assert eng.prefill_compiles == 0


def test_prefix_cache_composes_with_chunked_prefill():
    """prefix_cache + prefill_chunk: the warm request skips its cached
    full pages, streams only the suffix in chunks, and its tokens are
    bit-identical to the unchunked warm admission."""
    cfg, params = _tiny()
    rng = np.random.default_rng(22)
    system = rng.integers(0, cfg.vocab_size, 36)
    prompts = [np.concatenate([system,
                               rng.integers(0, cfg.vocab_size, 9 + i)])
               for i in range(2)]
    reqs = [Request(i, prompts[i], max_new=6, arrival=i) for i in range(2)]
    want = ContinuousEngine(cfg, params,
                            _scfg("nvfp4", prefix_cache=True)).run(reqs)
    eng = ContinuousEngine(cfg, params,
                           _scfg("nvfp4", prefix_cache=True,
                                 prefill_chunk=8))
    res = eng.run(reqs)
    sched = eng.scheduler
    for rid in (0, 1):
        np.testing.assert_array_equal(res[rid], want[rid])
    # DEFERRED insert: rid 1 arrived while rid 0 was still mid-chunked-
    # prefill, so rid 0's (partially unwritten) pages were NOT yet
    # registered — a later admission can never share unwritten pages
    assert sched.stats["prefix_tokens_skipped"] == 0
    fed = _assert_chunk_budget(sched.prefill_log, 8)
    assert fed == {0: len(prompts[0]), 1: len(prompts[1])}
    # second trace, SAME engine: the prefixes registered when their final
    # chunks issued — the warm rerun skips the 2 cached full pages,
    # streams only the suffix in chunks, and stays bit-identical
    mark = len(sched.prefill_log)
    warm = eng.run([Request(9, prompts[1], max_new=6)])
    assert eng.scheduler is sched
    assert sched.stats["prefix_tokens_skipped"] == 32
    fed2 = _assert_chunk_budget(sched.prefill_log[mark:], 8)
    assert fed2 == {9: len(prompts[1]) - 32}
    np.testing.assert_array_equal(warm[9], want[1])
    # the persisted scheduler keeps ONLY the cache's pages alive — every
    # slot-held page went back to the pool
    assert sched.active_slots() == []
    assert sched.pool.pages_in_use == sched.prefix_cache.cached_pages


# ---- the generated workload end-to-end ----------------------------------------


def test_workload_trace_end_to_end_reconciles():
    cfg, params = _tiny()
    wl = WorkloadConfig(tenants=(
        TenantSpec("chat", rate=0.6, prompt_lens=(6, 12),
                   system_prompt_len=16, max_new=6, deadline_slack=20),
        TenantSpec("flaky", rate=0.3, prompt_lens=(24,), max_new=6,
                   abort_prob=0.5, abort_after=2, timeout=30),
    ), ticks=10, seed=5, vocab=cfg.vocab_size)
    reqs = as_requests(generate_workload(wl))
    assert len(reqs) >= 4                  # seeded: the trace is non-trivial
    eng = ContinuousEngine(cfg, params,
                           _scfg("nvfp4", prefix_cache=True,
                                 prefill_chunk=16))
    res = eng.run(reqs)
    sched, ms = eng.scheduler, eng.metrics.summary()
    # every request accounted for exactly once, metrics == scheduler truth
    assert set(res) | set(sched.cancelled) == {r.rid for r in reqs}
    assert set(res) & set(sched.cancelled) == set()
    assert ms["submitted"] == len(reqs)
    assert ms["completed"] == len(res) == sched.stats["completed"]
    assert ms["cancelled"] == len(sched.cancelled) == \
        sched.stats["cancelled"]
    assert 0.0 <= ms["goodput"] <= 1.0
    assert ms["ttft_ticks"]["n"] >= ms["completed"]
    if ms["completed"]:
        assert ms["ttft_ticks"]["p50"] <= ms["ttft_ticks"]["p95"] \
            <= ms["ttft_ticks"]["p99"]
    assert ms["ticks"] == len(eng.metrics.queue_depth) > 0
    # the chat tenant's shared system prompt fed the prefix cache
    assert sched.stats["prefix_tokens_skipped"] > 0
    _assert_chunk_budget(sched.prefill_log, 16)
    # nothing leaked: only the prefix cache's own pages stay alive
    assert sched.active_slots() == []
    assert sched.pool.pages_in_use == sched.prefix_cache.cached_pages
