"""Speculative decoding on the bit-exact paged engine (PR 9).

Layers of evidence:
  * EXACTNESS: speculative greedy streams are BIT-identical to the
    non-speculative engine across every KV format (nvfp4/fp8/bf16),
    every draft depth, and composed with chunked prefill + the prefix
    cache — greedy verify accepts exactly the longest prefix the target
    would have produced sequentially, so acceptance only moves
    throughput, never tokens (strict equality, no margin gate);
  * the cache primitives underneath: ``write_tokens`` lands the same
    RtN rows as sequential ``write_token`` calls, and ``truncate_to``
    rolls rejected rows back exactly (the next append overwrites them
    in place — no zeroing pass to diverge bit-wise);
  * the FIVE-program contract: spec mode compiles the verify program
    exactly once, never touches the plain decode program, and the jit
    caches all stay at one entry across admissions and preemptions;
  * LIFECYCLE: cancel/expire/preempt landing on any tick of the
    draft -> verify -> rollback cycle leak nothing — page/slot refcount
    conservation holds after every tick, no live row aliases a page or
    points at TRASH early, and partial-suffix preemption resumes
    mid-stream bit-identically (spec and non-spec);
  * metrics: the accepted-tokens/tick/slot trajectory reconciles with
    the committed streams, and a full-depth draft (the draft IS the
    target) accepts everything — acceptance rate exactly 1.0.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hyp import given, settings, strategies as st

from repro.configs import get_config
from repro.models import registry
from repro.models.layers import TRASH_PAGE, PagedKVCache
from repro.serve import ContinuousEngine, Request, Scheduler, ServeConfig

FMTS = ("nvfp4", "fp8", "bf16")
NO_EOS = -1
PROMPT_LENS = (33, 12, 37)      # straddle 2 pages / sub-page / straddle 2

_STATE = {}


def _tiny():
    if "cfg" not in _STATE:
        _STATE["cfg"] = get_config("llama2-60m").smoke()
        _STATE["params"] = registry.init_params(_STATE["cfg"],
                                                jax.random.PRNGKey(0))
    return _STATE["cfg"], _STATE["params"]


def _scfg(fmt, **kw):
    return ServeConfig(batch_size=2, max_len=96, eos_id=NO_EOS,
                       kv_cache_format=fmt, page_size=16, **kw)


def _requests(cfg, max_new=12):
    rng = np.random.default_rng(7)
    return [Request(i, rng.integers(0, cfg.vocab_size, n), max_new=max_new)
            for i, n in enumerate(PROMPT_LENS)]


_BASELINE = {}      # fmt -> {rid: tokens}: NON-speculative reference


def _baseline(fmt):
    if fmt not in _BASELINE:
        cfg, params = _tiny()
        eng = ContinuousEngine(cfg, params, _scfg(fmt))
        _BASELINE[fmt] = eng.run(_requests(cfg))
    return _BASELINE[fmt]


def _rand(shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape)
                       .astype(np.float32)).astype(dtype)


# ---- cache primitives: batched write + exact rollback -------------------------


@pytest.mark.parametrize("fmt", FMTS)
def test_write_tokens_matches_sequential_write_token(fmt):
    """The S-row verify write lands bit-identical pool contents and
    lengths to S sequential decode writes (same RtN grid, same rows)."""
    B, S, KVH, D = 2, 5, 2, 32
    k, v = _rand((B, S, KVH, D), 1), _rand((B, S, KVH, D), 2)
    base = PagedKVCache.init(B, 32, KVH, D, fmt=fmt, page_size=8)
    perm = np.random.default_rng(0).permutation(np.arange(1, 9)).reshape(2, 4)
    base = dataclasses.replace(base, page_table=jnp.asarray(perm, jnp.int32),
                               lengths=jnp.asarray([3, 7], jnp.int32))
    blk = base.write_tokens(k, v)
    seq = base
    for t in range(S):
        seq = seq.write_token(k[:, t:t + 1], v[:, t:t + 1])
    np.testing.assert_array_equal(np.asarray(blk.lengths),
                                  np.asarray(seq.lengths))
    for a, b in zip((blk.k_codes, blk.k_scales, blk.v_codes, blk.v_scales),
                    (seq.k_codes, seq.k_scales, seq.v_codes, seq.v_scales)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_write_tokens_masked_slots_touch_nothing():
    """Masked-off slots (mid-chunked-prefill) write only the TRASH page
    and keep their length — their real pages are bit-untouched."""
    B, S, KVH, D = 2, 4, 2, 32
    k, v = _rand((B, S, KVH, D), 3), _rand((B, S, KVH, D), 4)
    base = PagedKVCache.init(B, 32, KVH, D, fmt="nvfp4", page_size=8)
    perm = np.random.default_rng(1).permutation(np.arange(1, 9)).reshape(2, 4)
    base = dataclasses.replace(base, page_table=jnp.asarray(perm, jnp.int32),
                               lengths=jnp.asarray([6, 9], jnp.int32))
    out = base.write_tokens(k, v, mask=jnp.asarray([True, False]))
    assert np.asarray(out.lengths).tolist() == [10, 9]
    live1 = np.asarray(perm[1])             # slot 1's pages: untouched
    np.testing.assert_array_equal(np.asarray(out.k_codes[live1]),
                                  np.asarray(base.k_codes[live1]))
    np.testing.assert_array_equal(np.asarray(out.v_codes[live1]),
                                  np.asarray(base.v_codes[live1]))


@pytest.mark.parametrize("fmt", FMTS)
def test_truncate_to_rollback_is_exact(fmt):
    """write k rows -> roll back r -> rewrite: the final cache is
    bit-identical to one that never saw the rejected rows (stale codes
    beyond ``lengths`` are invisible to dequant and overwritten in
    place by the next append)."""
    B, KVH, D, kk = 2, 2, 32, 4
    base = PagedKVCache.init(B, 32, KVH, D, fmt=fmt, page_size=8)
    base = dataclasses.replace(base,
                               page_table=jnp.asarray(
                                   np.arange(1, 9).reshape(2, 4), jnp.int32),
                               lengths=jnp.asarray([5, 11], jnp.int32))
    drafted = _rand((B, kk, KVH, D), 5), _rand((B, kk, KVH, D), 6)
    accepted = jnp.asarray([2, 4], jnp.int32)     # n_emit per slot
    rolled = base.write_tokens(*drafted).truncate_to(
        None, base.lengths + accepted)
    np.testing.assert_array_equal(np.asarray(rolled.lengths),
                                  np.asarray(base.lengths + accepted))
    # a cache that only ever appended the accepted rows reads identically
    ref = base
    for t in range(kk):
        m = accepted > t
        ref = ref.write_token(drafted[0][:, t:t + 1], drafted[1][:, t:t + 1],
                              mask=m)
    kd_r, vd_r = rolled.dequant(jnp.float32)
    kd_w, vd_w = ref.dequant(jnp.float32)
    for s in range(B):
        n = int(base.lengths[s] + accepted[s])
        np.testing.assert_array_equal(np.asarray(kd_r[s, :n]),
                                      np.asarray(kd_w[s, :n]))
        np.testing.assert_array_equal(np.asarray(vd_r[s, :n]),
                                      np.asarray(vd_w[s, :n]))
    # truncate can never extend
    again = rolled.truncate_to(None, rolled.lengths + 100)
    np.testing.assert_array_equal(np.asarray(again.lengths),
                                  np.asarray(rolled.lengths))


# ---- exactness: speculative == sequential, every format x draft depth ---------


@pytest.mark.parametrize("fmt", FMTS)
def test_spec_bit_identical_every_format(fmt):
    cfg, params = _tiny()
    want = _baseline(fmt)
    eng = ContinuousEngine(cfg, params, _scfg(fmt, spec_k=3, draft_layers=1))
    res = eng.run(_requests(cfg))
    assert set(res) == set(want)
    for rid in sorted(want):
        np.testing.assert_array_equal(
            res[rid], want[rid],
            err_msg=f"rid {rid} diverged under spec decoding fmt={fmt}")
    # the five-program contract: verify compiled once, plain decode NEVER
    assert eng.verify_compiles == 1
    assert eng.decode_compiles == 0
    assert eng.prefill_compiles == 1
    ms = eng.metrics.summary()
    acc = ms["spec_accepted_per_tick_slot"]
    assert acc["n"] > 0 and 1.0 <= acc["mean"] <= 3.0
    assert 0.0 <= ms["spec_acceptance_rate"]["mean"] <= 1.0


@pytest.mark.parametrize("draft_layers", (1, 2))
def test_spec_draft_depth_sweep_bit_identical(draft_layers):
    cfg, params = _tiny()
    want = _baseline("nvfp4")
    eng = ContinuousEngine(cfg, params,
                           _scfg("nvfp4", spec_k=4,
                                 draft_layers=draft_layers))
    res = eng.run(_requests(cfg))
    for rid in sorted(want):
        np.testing.assert_array_equal(res[rid], want[rid])


def test_spec_full_depth_draft_accepts_everything():
    """draft_layers == n_layers: the draft IS the target, so greedy
    verify agrees on every proposal — acceptance rate exactly 1.0 and
    k tokens per slot per verify tick (the speculative speedup
    ceiling, and the sharpest exactness probe: ANY draft/verify
    divergence would show up as acceptance < 1)."""
    cfg, params = _tiny()
    k = 4
    eng = ContinuousEngine(cfg, params,
                           _scfg("nvfp4", spec_k=k,
                                 draft_layers=cfg.n_layers))
    res = eng.run(_requests(cfg))
    want = _baseline("nvfp4")
    for rid in sorted(want):
        np.testing.assert_array_equal(res[rid], want[rid])
    ms = eng.metrics.summary()
    assert ms["spec_acceptance_rate"]["mean"] == 1.0
    assert ms["spec_accepted_per_tick_slot"]["mean"] == float(k)
    assert ms["spec_accepted_per_tick_slot"]["p99"] == float(k)


def test_spec_composes_with_chunked_prefill_and_prefix_cache():
    # baseline shares the admission path (suffix prefill attends THROUGH
    # quantized pages — a different, equally exact stream from the plain
    # prefill program); only spec on/off differs
    cfg, params = _tiny()
    want = ContinuousEngine(
        cfg, params, _scfg("nvfp4", prefill_chunk=5,
                           prefix_cache=True)).run(_requests(cfg))
    eng = ContinuousEngine(cfg, params,
                           _scfg("nvfp4", spec_k=3, draft_layers=1,
                                 prefill_chunk=5, prefix_cache=True))
    res = eng.run(_requests(cfg))
    for rid in sorted(want):
        np.testing.assert_array_equal(res[rid], want[rid])
    assert eng.verify_compiles == 1
    assert eng.chunk_compiles == 1
    assert eng.prefill_suffix_compiles == 1
    assert eng.prefill_compiles == 0 and eng.decode_compiles == 0
    assert eng.scheduler.pool.pages_in_use == \
        eng.scheduler.prefix_cache.cached_pages


def test_spec_metrics_reconcile_with_streams():
    """The accepted-tokens trajectory reconciles with the committed
    streams: every committed token beyond each request's prefill-sampled
    first one was emitted by a verify tick, and the only slack is the
    final tick's overshoot past max_new (at most k-1 per request, which
    ``commit`` clamps off the stream)."""
    cfg, params = _tiny()
    k = 3
    eng = ContinuousEngine(cfg, params, _scfg("nvfp4", spec_k=k,
                                              draft_layers=1))
    res = eng.run(_requests(cfg))
    met = eng.metrics
    committed = sum(len(t) for t in res.values())
    from_verify = committed - len(res)        # first tokens come from prefill
    assert from_verify <= sum(met.spec_accepted) \
        <= from_verify + len(res) * (k - 1)
    assert all(1 <= n <= k for n in met.spec_accepted)
    assert len(met.spec_accepted) == len(met.spec_rate)


# ---- config surface -----------------------------------------------------------


def test_spec_config_validation():
    cfg, params = _tiny()
    with pytest.raises(ValueError, match="spec_k"):
        ContinuousEngine(cfg, params, _scfg("nvfp4", spec_k=1))
    with pytest.raises(ValueError, match="draft_layers"):
        ContinuousEngine(cfg, params, _scfg("nvfp4", draft_layers=1))
    with pytest.raises(ValueError, match="draft_layers"):
        ContinuousEngine(cfg, params,
                         _scfg("nvfp4", spec_k=2,
                               draft_layers=cfg.n_layers + 1))
    with pytest.raises(NotImplementedError, match="greedy"):
        ContinuousEngine(cfg, params,
                         _scfg("nvfp4", spec_k=2, temperature=0.7))
    swa = dataclasses.replace(cfg, sliding_window=16)
    with pytest.raises(NotImplementedError, match="SWA"):
        ContinuousEngine(swa, registry.init_params(swa, jax.random.PRNGKey(0)),
                         _scfg("nvfp4", spec_k=2))


def test_spec_rejects_teacher_forcing():
    cfg, params = _tiny()
    eng = ContinuousEngine(cfg, params, _scfg("nvfp4", spec_k=2))
    reqs = _requests(cfg)
    with pytest.raises(NotImplementedError, match="forced"):
        eng.run(reqs, forced={0: np.zeros(4, np.int32)})


# ---- partial-suffix preemption: resume mid-stream, bit-identical --------------


@pytest.mark.parametrize("extra", ({}, {"spec_k": 3, "draft_layers": 1}),
                         ids=("plain", "spec"))
def test_partial_suffix_preemption_resumes_bit_identical(extra):
    """An 8-page pool forces preemption mid-decode.  With the prefix
    cache on, the victim's computed pages are adopted and it resumes
    from its partial stream (prefilling only the suffix) — the final
    streams are bit-identical to an unconstrained pool, spec and
    non-spec.  The requeued effective prompt carries written + 1 tokens
    (the last committed token's row is written by the resume prefill)."""
    cfg, params = _tiny()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (33, 37)]

    def run(total_pages):
        eng = ContinuousEngine(
            cfg, params, _scfg("nvfp4", total_pages=total_pages,
                               prefix_cache=True, **extra))
        res = eng.run([Request(i, p, max_new=24) for i, p in
                       enumerate(prompts)])
        return res, eng

    want, _ = run(None)
    got, eng = run(8)
    sched = eng.scheduler
    assert sched.stats["preemptions"] >= 1
    for rid in (0, 1):
        np.testing.assert_array_equal(
            got[rid], want[rid],
            err_msg=f"rid {rid} diverged across preemption")
    # jit caches still exactly one entry each — preemption/resume reuses
    # the compiled programs
    if extra:
        assert eng.verify_compiles == 1 and eng.decode_compiles == 0
    else:
        assert eng.decode_compiles == 1
    assert eng.prefill_suffix_compiles == 1
    assert sched.active_slots() == []
    assert sched.pool.pages_in_use == sched.prefix_cache.cached_pages


# ---- lifecycle: cancel/expire/preempt across the draft/verify cycle -----------


@settings(max_examples=8, deadline=None)
@given(abort_tick=st.integers(min_value=0, max_value=5),
       accepted_seed=st.integers(min_value=0, max_value=7))
def test_spec_lifecycle_conservation_at_any_stage(abort_tick, accepted_seed):
    """Host-side sweep of the spec-mode scheduler protocol
    (ensure_capacity(k, advance=False) -> advance_written(n) -> commit)
    with a victim aborted at every tick and RANDOM accepted lengths
    1..k per slot per tick.  After every tick: pool refcounts conserve
    (free + in_use == usable), no live row aliases a page or holds
    TRASH inside its allocated prefix, and at the end nothing leaks."""
    k = 3
    sched = Scheduler(n_slots=2, max_len=32, page_size=4)
    usable = sched.total_pages - 1
    rng = np.random.default_rng(accepted_seed)
    sched.submit(Request(0, np.arange(10, dtype=np.int32), max_new=6))
    sched.submit(Request(1, np.arange(9, dtype=np.int32), max_new=6,
                         abort_at=abort_tick))
    sched.submit(Request(2, np.arange(8, dtype=np.int32), max_new=5,
                         arrival=1))
    for tick in range(40):
        sched.expire(tick)
        sched.admit(tick)
        active = sched.decoding_slots()
        sched.ensure_capacity(k if active else 0, advance=False)
        for slot in list(active):
            if sched.slots[slot] is None:       # preempted this tick
                continue
            n = int(rng.integers(1, k + 1))
            sched.advance_written(slot, n)
            sched.commit(slot, np.full((n,), 7, np.int32), NO_EOS)
        assert sched.pool.free_pages + sched.pool.pages_in_use == usable
        live = []
        for slot in sched.active_slots():
            row = sched._rows[slot]
            npg = sched._npages[slot]
            assert (row[:npg] != TRASH_PAGE).all()
            assert (row[npg:] == TRASH_PAGE).all()
            live += [p for p in row.tolist() if p != TRASH_PAGE]
        assert len(live) == len(set(live))
        if not sched.has_work():
            break
    assert not sched.has_work()
    assert sched.pool.pages_in_use == 0
    assert set(sched.results) | set(sched.cancelled) == {0, 1, 2}
    assert set(sched.results) & set(sched.cancelled) == set()


def test_spec_abort_and_timeout_mid_run_no_leak():
    """Engine-level: an abort and a timeout landing while spec decoding
    is live leak nothing and never perturb the survivor's stream."""
    cfg, params = _tiny()
    scfg = _scfg("nvfp4", spec_k=3, draft_layers=1)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (20, 18, 24)]
    eng = ContinuousEngine(cfg, params, scfg)
    res = eng.run([Request(0, prompts[0], max_new=16, abort_at=3),
                   Request(1, prompts[1], max_new=10),
                   Request(2, prompts[2], max_new=8, timeout=4,
                           arrival=1)])
    sched = eng.scheduler
    assert 0 in sched.cancelled and sched.cancelled[0]["reason"] == "abort"
    assert sched.pool.pages_in_use == 0
    solo = ContinuousEngine(cfg, params, scfg).run(
        [Request(1, prompts[1], max_new=10)])
    np.testing.assert_array_equal(res[1], solo[1])
