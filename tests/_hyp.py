"""Minimal deterministic stand-in for ``hypothesis``.

This container has no network access, so ``hypothesis`` cannot be
installed; without it four test modules fail at *collection*.  This shim
provides the tiny subset the suite uses — ``given``, ``settings`` and the
``integers`` / ``floats`` / ``sampled_from`` strategies — backed by fixed
deterministic example sweeps: boundary values first (min, max, zero /
midpoint), then seeded-PRNG draws, for exactly ``settings.max_examples``
examples.  No shrinking, no database — a property failure reports the
offending example in the assertion message like any parametrized test.

Usage (the import-guard pattern in the test modules):

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hyp import given, settings, strategies as st
"""
from __future__ import annotations

import functools
import types

import numpy as np

_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def example(self, i: int, rng: np.random.Generator):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value=None, max_value=None):
        self.lo = 0 if min_value is None else int(min_value)
        self.hi = self.lo + 2 ** 31 - 1 if max_value is None \
            else int(max_value)

    def example(self, i, rng):
        bounds = [self.lo, self.hi, (self.lo + self.hi) // 2]
        if i < len(bounds):
            return bounds[i]
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, min_value=None, max_value=None, allow_nan=None,
                 allow_infinity=None, width=None, **_ignored):
        self.lo = -1e9 if min_value is None else float(min_value)
        self.hi = 1e9 if max_value is None else float(max_value)

    def example(self, i, rng):
        bounds = [self.lo, self.hi]
        if self.lo <= 0.0 <= self.hi:
            bounds.append(0.0)
        if i < len(bounds):
            return bounds[i]
        return float(rng.uniform(self.lo, self.hi))


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def example(self, i, rng):
        if i < len(self.elements):
            return self.elements[i]
        return self.elements[int(rng.integers(len(self.elements)))]


class settings:  # noqa: N801 (mirrors the hypothesis API)
    def __init__(self, max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None,
                 **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._hyp_max_examples = self.max_examples
        return fn


def given(*strats, **kwstrats):
    """Run the test once per deterministic example (boundaries, then seeded
    random draws).  Composes with @settings above or below it."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hyp_max_examples",
                        getattr(fn, "_hyp_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = np.random.default_rng(0xF9A11BAC)
            for i in range(n):
                pos = tuple(s.example(i, rng) for s in strats)
                kw = {k: s.example(i, rng) for k, s in kwstrats.items()}
                try:
                    fn(*args, *pos, **kw, **kwargs)
                except BaseException as e:
                    e.args = (f"falsifying example #{i}: args={pos} "
                              f"kwargs={kw}: {e.args[0] if e.args else e}",
                              ) + e.args[1:]
                    raise

        # hide the example parameters from pytest's fixture resolution
        # (functools.wraps exposes them via __wrapped__)
        del wrapper.__wrapped__
        return wrapper

    return deco


strategies = types.SimpleNamespace(
    integers=_Integers, floats=_Floats, sampled_from=_SampledFrom)
