"""Continuous batching (PR 4): scheduler, paged NVFP4 KV cache, engine
token-identity, and the paged Pallas kernel.

Layers of evidence:
  * host-side scheduler invariants: FIFO admission, page-pool blocking on
    prompt pages (demand paging — see tests/test_prefix_cache.py for
    growth/preemption), slot free/reuse, deterministic tick accounting
    (no jax);
  * the paged cache's writes/reads match the non-paged packed cache
    bit-tight, and the per-slot fused read matches the ``ref.py`` paged
    oracle (as does ``flash_attention_paged`` in interpret mode, across
    GQA/SWA/per-slot-length sweeps and a permuted page table);
  * continuous-batched greedy decode is TOKEN-IDENTICAL to the lockstep
    engine for the same arrival order — including a slot freed mid-run
    and reused by a queued request — under nvfp4/fp8/bf16 cache formats,
    with the greedy-margin guard allowing disagreement only across
    near-tied logit rows (the smoke-model caveat: random-init logits are
    near-flat, so ties are where bounded numeric differences may flip);
  * admission into a freed slot never recompiles (jit cache sizes == 1);
  * per-REQUEST sampling streams: a request's temperature>0 tokens do not
    depend on which slot or arrival order served it.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quantize import kv_quant_rows
from repro.kernels import ref
from repro.kernels.flash_attn import flash_attention_paged
from repro.models import registry
from repro.models.layers import (TRASH_PAGE, PackedKVCache, PagedKVCache,
                                 _attn_decode_packed, _attn_decode_paged)
from repro.serve import (ContinuousEngine, Engine, PagePool, Request,
                         Scheduler, ServeConfig)

FMTS = ("nvfp4", "fp8", "bf16")
NO_EOS = -1     # sentinel eos id that never matches a sampled token


def _rand(shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape)
                       .astype(np.float32)).astype(dtype)


# ---- host-side scheduler ------------------------------------------------------


def test_page_pool_alloc_free():
    pool = PagePool(8)                      # pages 1..7 usable (0 = trash)
    assert pool.free_pages == 7
    a = pool.alloc(3)
    assert len(a) == 3 and TRASH_PAGE not in a
    assert pool.alloc(5) is None            # only 4 left: alloc is atomic
    assert pool.free_pages == 4
    pool.free(a)
    assert pool.free_pages == 7
    with pytest.raises(ValueError, match="trash"):
        pool.free([TRASH_PAGE])


def test_scheduler_admission_and_reuse():
    sched = Scheduler(n_slots=2, max_len=32, page_size=8)
    for rid, (plen, mn, arr) in enumerate(((8, 8, 0), (8, 8, 0), (4, 4, 0))):
        sched.submit(Request(rid, np.zeros(plen, np.int32), mn, arr))
    placed = sched.admit(tick=0)
    assert [p[0] for p in placed] == [0, 1]          # FIFO into slots 0, 1
    assert sched.admit(tick=0) == []                 # rid 2 queued: no slot
    row0 = placed[0][2]
    # demand-driven paging: admission covers the PROMPT only (1 page for
    # plen 8); decode pages arrive tick by tick via ensure_capacity
    assert row0.shape == (4,) and (row0[:1] != TRASH_PAGE).all()
    assert (row0[1:] == TRASH_PAGE).all()
    # finish slot 0 -> pages return, rid 2 admitted into the freed slot
    sched.commit(0, np.asarray([5, 1]), eos_id=1)
    assert sched.slots[0] is None and 0 in sched.results
    placed = sched.admit(tick=0)
    assert [p[0] for p in placed] == [0] and placed[0][1].rid == 2


def test_scheduler_blocks_on_pages_not_just_slots():
    # pool sized so the first PROMPT leaves too few pages for the second:
    # the second request must wait even though a slot is free (demand
    # paging blocks admission on prompt pages, not the full lifetime)
    sched = Scheduler(n_slots=2, max_len=32, page_size=8, total_pages=5)
    sched.submit(Request(0, np.zeros(24, np.int32), 8, 0))    # 3 pages
    sched.submit(Request(1, np.zeros(16, np.int32), 16, 0))   # 2 pages
    assert [p[0] for p in sched.admit(0)] == [0]
    assert sched.admit(0) == []                      # 1 free page < 2
    sched.commit(0, np.asarray([7] * 8), eos_id=NO_EOS)
    assert [p[0] for p in sched.admit(0)] == [0]     # now it fits


def test_scheduler_rejects_oversize_request():
    sched = Scheduler(n_slots=1, max_len=16, page_size=8)
    with pytest.raises(ValueError, match="max_len"):
        sched.submit(Request(0, np.zeros(10, np.int32), 10))
    with pytest.raises(ValueError, match="pool"):
        Scheduler(n_slots=1, max_len=64, page_size=8, total_pages=4)


# ---- paged cache vs packed cache ----------------------------------------------


@pytest.mark.parametrize("fmt", FMTS)
def test_paged_write_matches_packed_storage(fmt):
    """Prompt + token writes through pages reconstruct the same rows as
    the non-paged packed cache (same RtN grid, page indirection only)."""
    B, S, KVH, D = 2, 24, 2, 32
    k, v = _rand((B, S, KVH, D), 1), _rand((B, S, KVH, D), 2)
    pc = PagedKVCache.init(B, 32, KVH, D, fmt=fmt, page_size=8)
    # hand out permuted pages (slot rows non-contiguous, out of order)
    perm = np.random.default_rng(0).permutation(np.arange(1, 9)).reshape(2, 4)
    pc = dataclasses.replace(pc, page_table=jnp.asarray(perm, jnp.int32))
    pc = pc.write_prompt(0, k[:1, :20], v[:1, :20], 20)
    pc = pc.write_prompt(1, k[1:, :20], v[1:, :20], 20)
    for t in range(20, 24):
        pc = pc.write_token(k[:, t:t + 1], v[:, t:t + 1])
    kd, vd = pc.dequant(jnp.float32)
    if fmt == "bf16":
        want_k = np.asarray(k.astype(jnp.bfloat16).astype(jnp.float32))
        want_v = np.asarray(v.astype(jnp.bfloat16).astype(jnp.float32))
    else:
        want_k, want_v = (np.asarray(_kv_roundtrip(x, fmt)) for x in (k, v))
    np.testing.assert_array_equal(np.asarray(kd[:, :S]), want_k)
    np.testing.assert_array_equal(np.asarray(vd[:, :S]), want_v)
    assert np.asarray(pc.lengths).tolist() == [24, 24]


def _kv_roundtrip(x, fmt):
    from repro.core.quantize import kv_dequant
    return kv_dequant(*kv_quant_rows(x, fmt), fmt, dtype=jnp.float32)


@pytest.mark.parametrize("fmt", ("nvfp4", "fp8"))
def test_paged_decode_read_matches_packed(fmt):
    """Per-slot paged read == per-row non-paged packed read with that
    row's scalar (kv_len, q_offset)."""
    B, S, H, KVH, D = 3, 32, 4, 2, 32
    k, v, q = _rand((B, S, KVH, D), 3), _rand((B, S, KVH, D), 4), \
        _rand((B, 1, H, D), 5)
    pc = PagedKVCache.init(B, S, KVH, D, fmt=fmt, page_size=8)
    perm = np.random.default_rng(1).permutation(
        np.arange(1, 1 + B * 4)).reshape(B, 4)
    pc = dataclasses.replace(pc, page_table=jnp.asarray(perm, jnp.int32))
    plens = [9, 32, 21]
    for i, pl in enumerate(plens):
        pc = pc.write_prompt(i, k[i:i + 1], v[i:i + 1], pl)
    lengths = pc.lengths
    out = _attn_decode_paged(
        q, pc, qpos=(lengths - 1)[:, None],
        kpos=jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S)),
        causal=True, window=None, kv_len=lengths, chunk=8)
    for i, pl in enumerate(plens):
        kc, ks = kv_quant_rows(k[i:i + 1], fmt)
        vc, vs = kv_quant_rows(v[i:i + 1], fmt)
        cache = PackedKVCache(kc, ks, vc, vs, jnp.asarray(pl), fmt, 16)
        want = _attn_decode_packed(
            q[i:i + 1], cache, qpos=jnp.asarray([pl - 1]),
            kpos=jnp.arange(S, dtype=jnp.int32), causal=True, window=None,
            kv_len=jnp.asarray(pl), chunk=8)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want[0]),
                                   rtol=2e-5, atol=2e-5)


# ---- paged Pallas kernel (interpret mode) vs ref oracle ------------------------


def _build_paged(fmt, window, B=3, KVH=2, D=32, psz=8, npg=4, seed=7):
    """A paged cache with permuted pages and three distinct per-slot
    lengths (one short, one exactly full, one wrapped for SWA)."""
    rng = np.random.default_rng(seed)
    buf = psz * npg
    pc = PagedKVCache.init(B, buf, KVH, D, fmt=fmt, page_size=psz)
    perm = rng.permutation(np.arange(1, 1 + B * npg)).reshape(B, npg)
    pc = dataclasses.replace(pc, page_table=jnp.asarray(perm, jnp.int32))
    pre = [12, buf, 27]
    for i, T in enumerate(pre):
        kv = [jnp.asarray(rng.standard_normal((1, T, KVH, D)), jnp.float32)
              for _ in range(2)]
        pc = pc.write_prompt(i, kv[0], kv[1], T)
    extra = 9 if window is not None else 0    # roll every slot past buf
    for _ in range(extra):
        k1 = jnp.asarray(rng.standard_normal((B, 1, KVH, D)), jnp.float32)
        v1 = jnp.asarray(rng.standard_normal((B, 1, KVH, D)), jnp.float32)
        pc = pc.write_token(k1, v1)
    return pc


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("window", [None, 24])
def test_flash_paged_kernel_matches_oracle(fmt, window):
    B, H, KVH, D = 3, 4, 2, 32            # GQA: 2 query heads per kv head
    pc = _build_paged(fmt, window)
    q = _rand((B, 1, H, D), 8)
    lengths = pc.lengths
    kv_len = jnp.minimum(lengths, pc.buf)
    q_off = lengths - 1
    out = flash_attention_paged(
        q, pc.k_codes, pc.k_scales, pc.v_codes, pc.v_scales, pc.page_table,
        kv_len, q_off, fmt=fmt, causal=True, window=window, interpret=True)
    want = ref.paged_attention_ref(
        q, pc.k_codes, pc.k_scales, pc.v_codes, pc.v_scales, pc.page_table,
        kv_len, q_off, fmt=fmt, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_paged_kernel_rejects_bad_layout():
    pc = _build_paged("nvfp4", None)
    q = _rand((3, 1, 4, 32), 9)
    with pytest.raises(ValueError, match="format"):
        flash_attention_paged(q, pc.k_codes, pc.k_scales, pc.v_codes,
                              pc.v_scales, pc.page_table, pc.lengths,
                              pc.lengths, fmt="int4", interpret=True)
    with pytest.raises(ValueError, match="layout"):
        flash_attention_paged(q, pc.k_codes[..., :8], pc.k_scales,
                              pc.v_codes[..., :8], pc.v_scales,
                              pc.page_table, pc.lengths, pc.lengths,
                              fmt="nvfp4", interpret=True)


# ---- engine-level: continuous == lockstep --------------------------------------


@pytest.fixture(scope="module")
def tiny():
    return get_config("llama2-60m").smoke()


@pytest.fixture(scope="module")
def tiny_params(tiny):
    return registry.init_params(tiny, jax.random.PRNGKey(0))


def _scfg(fmt="nvfp4", slots=2, **kw):
    kw.setdefault("eos_id", NO_EOS)
    kw.setdefault("decode_chunk", 4)
    return ServeConfig(batch_size=slots, max_len=64, kv_cache_format=fmt,
                       page_size=16, **kw)


def _assert_tokens_match(got, want, margins, tol=0.02, min_agree=0.8):
    """Token identity with the smoke-model near-tie caveat: disagreement
    is only tolerated on steps whose greedy margin is below ``tol`` (the
    near-flat random-init logit rows), and must stay rare."""
    got, want = np.asarray(got), np.asarray(want)
    n = min(len(got), len(want))
    neq = got[:n] != want[:n]
    if neq.any():
        assert (np.asarray(margins)[:n][neq] < tol).all(), \
            f"token mismatch at decisive steps: {np.nonzero(neq)[0]}"
    assert np.mean(~neq) >= min_agree


@pytest.mark.parametrize("fmt", FMTS)
def test_continuous_matches_lockstep_same_arrival(tiny, tiny_params, fmt):
    """Same arrival order, equal-length prompts: greedy continuous decode
    is token-identical to the lockstep engine (margin-gated)."""
    scfg = _scfg(fmt)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, tiny.vocab_size, 8) for _ in range(2)]
    out_l = Engine(tiny, tiny_params, scfg).generate(prompts, max_new=8)
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    out_c = eng.generate(prompts, max_new=8)
    for i in range(2):
        _assert_tokens_match(out_c[i], out_l[i], eng.margins[i])


def test_slot_reuse_queued_request_no_recompile(tiny, tiny_params):
    """3 requests over 2 slots (nvfp4 default): rid 0 finishes early, the
    QUEUED rid 2 lands in its freed slot; every request is token-identical
    to a solo lockstep run, and neither compiled program retraced."""
    scfg = _scfg("nvfp4")
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, tiny.vocab_size, n) for n in (8, 6, 5)]
    budgets = (4, 14, 6)
    reqs = [Request(rid, prompts[rid], max_new=budgets[rid],
                    arrival=(1 if rid == 2 else 0)) for rid in range(3)]
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    res = eng.run(reqs)
    assert eng.prefill_compiles == 1 and eng.decode_compiles == 1
    assert eng.scheduler.stats["completed"] == 3
    solo = Engine(tiny, tiny_params,
                  ServeConfig(batch_size=1, max_len=64, eos_id=NO_EOS,
                              kv_cache_format="nvfp4"))
    for rid in range(3):
        want = solo.generate([prompts[rid]], max_new=budgets[rid])[0]
        _assert_tokens_match(res[rid], want, eng.margins[rid])


def test_teacher_forced_stream_comparison(tiny, tiny_params):
    """The forced-token hook: feed the lockstep stream into the continuous
    engine and compare its RECORDED picks step-by-step (margin-gated) —
    the pure teacher-forced form of the identity claim."""
    scfg = _scfg("nvfp4")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, tiny.vocab_size, 7) for _ in range(2)]
    out_l = Engine(tiny, tiny_params, scfg).generate(prompts, max_new=8)
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    reqs = [Request(i, prompts[i], max_new=8) for i in range(2)]
    res = eng.run(reqs, forced={i: out_l[i] for i in range(2)})
    for i in range(2):
        _assert_tokens_match(res[i], out_l[i], eng.margins[i])


def test_per_request_sampling_stream_survives_slot_change(tiny, tiny_params):
    """temperature>0: a request's sampled tokens are keyed by REQUEST id,
    so serving it alone vs after other traffic (different slot, different
    arrival tick) yields the same stream — slot reuse never replays or
    shifts another request's randomness."""
    scfg = _scfg("nvfp4", temperature=0.8, top_k=16)
    rng = np.random.default_rng(3)
    prompt7 = rng.integers(0, tiny.vocab_size, 6)
    other = rng.integers(0, tiny.vocab_size, 8)
    eng = ContinuousEngine(tiny, tiny_params, scfg)
    solo = eng.run([Request(7, prompt7, max_new=6)])
    mixed = eng.run([Request(1, other, max_new=8, arrival=0),
                     Request(2, other, max_new=4, arrival=0),
                     Request(7, prompt7, max_new=6, arrival=1)])
    np.testing.assert_array_equal(solo[7], mixed[7])
    assert eng.prefill_compiles == 1 and eng.decode_compiles == 1


def test_lockstep_tick_sync_invariant(tiny, tiny_params):
    """The once-per-tick host sync (decode_chunk) must not change lockstep
    outputs: chunk=1 (old per-token cadence) == chunk=5."""
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, tiny.vocab_size, 8) for _ in range(2)]
    outs = []
    for chunk in (1, 5):
        scfg = _scfg("nvfp4", decode_chunk=chunk)
        outs.append(Engine(tiny, tiny_params, scfg).generate(prompts,
                                                             max_new=7))
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


def test_lockstep_eos_early_stop(tiny, tiny_params):
    """EOS bookkeeping on device: pick the first greedily generated token
    as the eos id — the row must terminate and pad with it."""
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, tiny.vocab_size, 8)]
    probe = Engine(tiny, tiny_params, _scfg()).generate(prompts, max_new=1)
    eos = int(probe[0][0])
    eng = Engine(tiny, tiny_params, _scfg(eos_id=eos))
    out = eng.generate(prompts, max_new=12)
    o = out[0]
    assert eos in o
    i = int(np.argmax(o == eos))
    assert (o[i:] == eos).all()              # eos-padded after done


def test_continuous_rejects_recurrent_families():
    cfg = get_config("zamba2-1.2b").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="lockstep"):
        ContinuousEngine(cfg, params, _scfg())
    # ...but the hybrid family's shared-attn caches can still be built
    # paged (per-slot lengths thread through init_cache)
    carry = registry.make_decode_state(cfg, 2, 64, kv_cache_format="nvfp4",
                                       page_size=16)
    assert all(isinstance(c, PagedKVCache) for c in carry[1])


def test_continuous_rejects_oversize_prompt(tiny, tiny_params):
    eng = ContinuousEngine(tiny, tiny_params, _scfg())
    with pytest.raises(ValueError, match="max_len"):
        eng.run([Request(0, np.zeros(60, np.int32), max_new=30)])


@pytest.mark.slow
def test_whisper_continuous_matches_lockstep():
    """encdec: per-slot decoder caches + per-slot pos_dec gather.  Two
    requests with different prompt lengths match their solo lockstep
    runs (same frames)."""
    cfg = get_config("whisper-base").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    frames = [jnp.asarray(rng.standard_normal((1, cfg.enc_seq, cfg.d_model)),
                          jnp.bfloat16) for _ in range(2)]
    prompts = [rng.integers(0, cfg.vocab_size, n) for n in (6, 4)]
    scfg = _scfg()
    eng = ContinuousEngine(cfg, params, scfg)
    reqs = [Request(i, prompts[i], max_new=6) for i in range(2)]
    res = eng.run(reqs, extras={i: {"frames": frames[i]} for i in range(2)})
    solo = Engine(cfg, params, ServeConfig(batch_size=1, max_len=64,
                                           eos_id=NO_EOS))
    for i in range(2):
        want = solo.generate([prompts[i]], max_new=6,
                             extras={"frames": frames[i]})[0]
        _assert_tokens_match(res[i], want, eng.margins[i])


def test_swa_continuous_decode_past_window(tiny, tiny_params):
    """Dense SWA (window 32): continuous decode past the rolling-buffer
    wrap is token-identical to the solo lockstep engine — the rolling
    buffer migrated onto pages (``pos % buf`` through the page table)."""
    cfg = dataclasses.replace(tiny, sliding_window=32)
    scfg = ServeConfig(batch_size=2, max_len=64, eos_id=NO_EOS,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=4)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, 28),
               rng.integers(0, cfg.vocab_size, 14)]
    eng = ContinuousEngine(cfg, tiny_params, scfg)
    out = eng.generate(prompts, max_new=8)      # 28 + 8 > window=32: wraps
    solo = Engine(cfg, tiny_params, ServeConfig(batch_size=1, max_len=64,
                                                eos_id=NO_EOS,
                                                kv_cache_format="nvfp4"))
    for i in range(2):
        want = solo.generate([prompts[i]], max_new=8)[0]
        _assert_tokens_match(out[i], want, eng.margins[i])


@pytest.mark.slow
def test_moe_swa_continuous_liveness():
    """MoE + SWA (mixtral smoke): token-IDENTITY to lockstep does not
    apply — expert-capacity routing couples tokens across the whole
    (padded) batch, so per-request right-padded prefill legitimately
    routes differently than a lockstep batch.  The continuous engine must
    still serve the trace to completion with finite outputs, rolling
    wraps, slot reuse and no recompilation."""
    cfg = get_config("mixtral_8x7b").smoke()
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_size=2, max_len=128, eos_id=NO_EOS,
                       kv_cache_format="nvfp4", page_size=16,
                       decode_chunk=4)
    rng = np.random.default_rng(7)
    reqs = [Request(0, rng.integers(0, cfg.vocab_size, 60), max_new=8),
            Request(1, rng.integers(0, cfg.vocab_size, 30), max_new=6),
            Request(2, rng.integers(0, cfg.vocab_size, 20), max_new=4,
                    arrival=1)]                  # queued -> reused slot
    eng = ContinuousEngine(cfg, params, scfg)
    res = eng.run(reqs)                          # 60 + 8 > window=64: wraps
    assert eng.scheduler.stats["completed"] == 3
    assert eng.prefill_compiles == 1 and eng.decode_compiles == 1
    for rid, n in ((0, 8), (1, 6), (2, 4)):
        assert len(res[rid]) == n
        assert ((0 <= res[rid]) & (res[rid] < cfg.padded_vocab)).all()
