"""Tests for the FQT custom_vjp matmul (the paper's six quantization points)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import fqt
from repro.core.fqt import (QuantConfig, bf16_config, fp4_matmul, fqt_config,
                            nvfp4_paper_config, qaf_config, tseng2025_config,
                            wang2025_config, PAPER_SR_POINTS)
from repro.core.quantize import NVFP4, fake_quant


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * scale)


def test_bf16_config_is_exact():
    x, w = _rand((32, 64), 0), _rand((64, 48), 1)
    y = fp4_matmul(x, w, cfg=bf16_config())
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ np.asarray(w),
                               rtol=1e-6)


def test_forward_matches_manual_quantization():
    """[Forward] z = Q_rtn(a) @ Q_rtn(W), blocks along K."""
    x, w = _rand((32, 64), 2), _rand((64, 48), 3)
    cfg = nvfp4_paper_config()
    y = fp4_matmul(x, w, cfg=cfg, seed=jnp.uint32(7))
    qx = fake_quant(x, cfg.fwd_a, axis=-1)
    qw = fake_quant(w, cfg.fwd_w, axis=0)
    expected = jnp.matmul(qx, qw, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expected), rtol=1e-6)


def test_forward_deterministic_rtn():
    """Forward uses RtN only: independent of the SR seed."""
    x, w = _rand((16, 32), 4), _rand((32, 32), 5)
    cfg = nvfp4_paper_config()
    y1 = fp4_matmul(x, w, cfg=cfg, seed=jnp.uint32(0))
    y2 = fp4_matmul(x, w, cfg=cfg, seed=jnp.uint32(12345))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_backward_matches_manual_quantization():
    """[Backward] dX = Q_sr(g) Q_rtn(W^T); [Update] dW = Q_sr(a^T) Q_sr(g)."""
    x, w = _rand((32, 64), 6), _rand((64, 48), 7)
    c = _rand((32, 48), 8)
    cfg = nvfp4_paper_config()
    seed = jnp.uint32(99)

    def loss(x, w):
        return jnp.sum(fp4_matmul(x, w, cfg=cfg, seed=seed) * c)

    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)

    # manual replication with the same per-site SR streams
    g = c
    qg_b = fake_quant(g, cfg.bwd_g, axis=-1, u=fqt._site_u(seed, 2, g.shape))
    qw_b = fake_quant(w, cfg.bwd_w, axis=1)
    exp_dx = jnp.matmul(qg_b, qw_b.T, preferred_element_type=jnp.float32)
    qx_u = fake_quant(x, cfg.upd_a, axis=0, u=fqt._site_u(seed, 4, x.shape))
    qg_u = fake_quant(g, cfg.upd_g, axis=0, u=fqt._site_u(seed, 5, g.shape))
    exp_dw = jnp.matmul(qx_u.T, qg_u, preferred_element_type=jnp.float32)

    np.testing.assert_allclose(np.asarray(dx), np.asarray(exp_dx), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(exp_dw), rtol=1e-6)


def test_sr_seed_changes_backward_not_forward():
    x, w = _rand((32, 32), 9), _rand((32, 32), 10)
    cfg = nvfp4_paper_config()

    def grads(seed):
        def loss(x, w):
            return jnp.sum(fp4_matmul(x, w, cfg=cfg, seed=seed) ** 2)
        return jax.grad(loss, argnums=(0, 1))(x, w)

    dx1, dw1 = grads(jnp.uint32(1))
    dx2, dw2 = grads(jnp.uint32(2))
    assert not np.array_equal(np.asarray(dw1), np.asarray(dw2))
    assert not np.array_equal(np.asarray(dx1), np.asarray(dx2))
    # same seed => bit-identical (replayable after restart)
    dx1b, dw1b = grads(jnp.uint32(1))
    np.testing.assert_array_equal(np.asarray(dw1), np.asarray(dw1b))


def test_update_gemm_sr_unbiased():
    """E[dW] under SR equals the dequantization-free dW up to fwd quant:
    the core property that makes FP4 updates trainable (paper §4)."""
    x, w = _rand((64, 16), 11, 0.5), _rand((16, 16), 12, 0.5)
    c = _rand((64, 16), 13)
    cfg = fqt_config(NVFP4)  # SR at paper points

    def dw_for(seed):
        def loss(x, w):
            return jnp.sum(fp4_matmul(x, w, cfg=cfg, seed=seed) * c)
        return jax.grad(loss, argnums=1)(x, w)

    dws = jnp.stack([dw_for(jnp.uint32(i)) for i in range(64)])
    mean_dw = jnp.mean(dws, axis=0)
    exact_dw = jnp.asarray(np.asarray(x).T @ np.asarray(c))
    # SR noise std per entry ~ gap*scale/sqrt(draws); tolerance ~ 5 sigma
    resid = np.abs(np.asarray(mean_dw - exact_dw))
    tol = 5 * float(jnp.std(dws, axis=0).max()) / np.sqrt(64) + 5e-3
    assert resid.max() < tol + 0.15  # loose: fwd quant of x also perturbs dW


def test_small_batch_update_fallback():
    """M < block: update GEMM falls back to bf16 instead of failing."""
    x, w = _rand((4, 32), 14), _rand((32, 32), 15)

    def loss(x, w):
        return jnp.sum(fp4_matmul(x, w, cfg=nvfp4_paper_config(),
                                  seed=jnp.uint32(3)))
    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert np.isfinite(np.asarray(dx)).all() and np.isfinite(np.asarray(dw)).all()


def test_batched_input_3d():
    x = _rand((4, 16, 64), 16)
    w = _rand((64, 32), 17)
    cfg = nvfp4_paper_config()
    y = fp4_matmul(x, w, cfg=cfg, seed=jnp.uint32(0))
    assert y.shape == (4, 16, 32)
    dx, dw = jax.grad(lambda x, w: jnp.sum(
        fp4_matmul(x, w, cfg=cfg, seed=jnp.uint32(0)) ** 2),
        argnums=(0, 1))(x, w)
    assert dx.shape == x.shape and dw.shape == w.shape
    assert np.isfinite(np.asarray(dx)).all()


def test_jit_and_grad_compose():
    x, w = _rand((32, 32), 18), _rand((32, 32), 19)
    cfg = nvfp4_paper_config()

    @jax.jit
    def step(x, w, seed):
        return jax.grad(lambda w: jnp.sum(
            fp4_matmul(x, w, cfg=cfg, seed=seed) ** 2))(w)

    g1 = step(x, w, jnp.uint32(5))
    g2 = step(x, w, jnp.uint32(5))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_presets_table2():
    """Table 2: which GEMM operands each related work quantizes."""
    ours = nvfp4_paper_config()
    assert all(ours.spec(p) is not None for p in fqt.POINTS)
    assert {p for p in fqt.POINTS if ours.spec(p).stochastic} == set(PAPER_SR_POINTS)

    wang = wang2025_config()   # W/A only, grads BF16
    assert wang.bwd_g is None and wang.upd_g is None and wang.upd_a is None
    assert wang.fwd_w is not None and wang.fwd_a is not None

    tseng = tseng2025_config()  # grads only (MXFP4+SR)
    assert tseng.fwd_w is None and tseng.fwd_a is None
    assert tseng.bwd_g.stochastic and tseng.bwd_g.scale_fmt == "e8m0"

    qaf = qaf_config()          # FP4 fwd, BF16 bwd
    assert qaf.fwd_w is not None and qaf.bwd_g is None and qaf.upd_g is None


def test_bf16_weights_path_grad_exact():
    """QAF config: backward grads equal the exact grads of the quantized fwd
    (STE), since no backward/update quantization is applied."""
    x, w = _rand((32, 32), 20), _rand((32, 32), 21)
    cfg = qaf_config()
    c = _rand((32, 32), 22)

    dx, dw = jax.grad(lambda x, w: jnp.sum(
        fp4_matmul(x, w, cfg=cfg) * c), argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(c) @ np.asarray(w).T,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(x).T @ np.asarray(c),
                               rtol=1e-5, atol=1e-5)
