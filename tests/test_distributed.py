"""Distribution-layer tests: sharding rules, hlo_cost, compression math,
pipeline parallelism (multi-device cases run in a subprocess with forced
host devices so the main test process keeps its single real device)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import compression, sharding as shd
from repro.launch import hlo_cost


# ---- sharding rules (pure logic, no devices needed) ---------------------------


class _FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)
        size = 256


def test_param_spec_rules():
    mesh = _FakeMesh()
    assert shd.param_spec("layers/attn/wq", 3, mesh) == P(None, "data",
                                                          "model")
    assert shd.param_spec("layers/attn/wo", 3, mesh) == P(None, "model",
                                                          "data")
    assert shd.param_spec("layers/mlp/w_down", 3, mesh) == P(None, "model",
                                                             "data")
    assert shd.param_spec("embed", 2, mesh) == P("model", "data")
    assert shd.param_spec("lm_head", 2, mesh) == P("data", "model")
    assert shd.param_spec("layers/ln1", 2, mesh) == P()
    assert shd.param_spec("moe/w_gate", 4, mesh) == P(None, None, "data",
                                                      "model")
    assert shd.param_spec("mamba/in_proj", 3, mesh) == P(None, "data",
                                                         "model")


def test_divisible_drops_odd_axes():
    mesh = _FakeMesh()
    # 40 heads * 128 hd = 5120 divisible; but a dim of 10 is not
    assert shd._divisible(P("data", "model"), (10, 5120), mesh) == \
        P(None, "model")
    # fully-dropped specs come back in CANONICAL form (trailing Nones
    # stripped): P() == P(None, None) to GSPMD but not to the jit compile
    # cache's sharding equality, which is why _divisible normalizes
    # fp4lint: disable=spec-canonical  (non-canonical input is the point)
    assert shd._divisible(P(("pod", "data"), None), (10, 64),
                          _FakeMesh()) == P()


def test_constrain_noop_without_scope():
    x = jnp.ones((4, 8))
    assert shd.constrain(x, "res") is x


# ---- hlo_cost: trip-count-aware analysis ---------------------------------------


def test_hlo_cost_counts_scan_trips():
    """A scan of 8 matmuls must report 8× the flops of one matmul (XLA's
    own cost_analysis reports 1× — the whole reason hlo_cost exists)."""
    M = 128
    w = jax.ShapeDtypeStruct((8, M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def f(x, w):
        def body(h, wi):
            return jnp.tanh(jnp.dot(h, wi)), None
        return jax.lax.scan(body, x, w)[0]

    compiled = jax.jit(f).lower(x, w).compile()
    c = hlo_cost.analyze(compiled.as_text())
    assert c.flops == pytest.approx(8 * 2 * M ** 3, rel=0.01)
    # weight traffic: 8 slices of M*M*4 bytes, NOT 8 full stacks (whole
    # stack per iteration would be 8*8*M*M*4 = 4.2 MB; allow fusion slack)
    assert c.bytes < 8 * (12 * M * M * 4)
    assert c.bytes_min <= c.bytes


def test_hlo_cost_simple_dot():
    M, K, N = 64, 128, 32
    f = jax.jit(lambda a, b: a @ b)
    compiled = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                       jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    c = hlo_cost.analyze(compiled.as_text())
    assert c.flops == pytest.approx(2 * M * K * N, rel=0.01)
    assert c.bytes >= (M * K + K * N + M * N) * 4


# ---- gradient compression -------------------------------------------------------


def test_compression_ratio():
    assert compression.compression_ratio(compression.GRAD_FP8) == \
        pytest.approx(16 / 8.25, rel=1e-6)
    assert compression.compression_ratio(compression.GRAD_FP4) == \
        pytest.approx(16 / 4.5, rel=1e-6)


def test_compressed_grads_unbiased_and_close():
    """E4M3+SR compression noise is zero-mean (up to the documented
    amax tail-clipping) and small relative to gradient scale — the
    property the §4 threshold analysis relies on."""
    from repro.core.quantize import block_quantize, fake_quant
    g = jax.random.normal(jax.random.PRNGKey(0), (4, 512), jnp.float32)
    spec = compression.GRAD_FP8
    draws = jnp.stack([fake_quant(g, spec, key=jax.random.PRNGKey(i))
                       for i in range(64)])
    # representable ceiling per block: data.max * scale * tscale; elements
    # above it saturate deterministically (tail clipping — same in HW)
    qt = block_quantize(g, spec, key=jax.random.PRNGKey(0))
    ceil = spec.data.max * jnp.repeat(qt.scales, spec.block, 1) * qt.tscale
    clipped = np.asarray(jnp.abs(g) > ceil)
    bias = np.abs(np.asarray(draws.mean(0) - g))
    # SR is unbiased; the 64-draw mean deviates by at most ~gap*5/16
    # (binomial SE, 5 sigma) where gap is the local grid spacing in
    # dequant space: gap = ulp(x_scaled) * scale * tscale
    denom = np.asarray(jnp.repeat(qt.scales, spec.block, 1) * qt.tscale)
    xhat = np.abs(np.asarray(g)) / denom
    ulp = 2.0 ** (np.floor(np.log2(np.maximum(xhat, 2.0 ** -6)))
                  - spec.data.man_bits)
    gap = ulp * denom
    ok = bias <= 0.5 * gap + 1e-5
    assert ok[~clipped].all(), bias[~clipped & ~ok].max()
    assert clipped.mean() < 0.02        # clipping is rare
    rel_noise = float(jnp.std(draws[0] - g) / jnp.std(g))
    assert rel_noise < 0.05     # |noise| << gradient scale


_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.distributed.pipeline import PipelineConfig, pipeline_apply
    from repro.distributed.compression import (CompressionConfig,
                                               pod_mean_grads, GRAD_FP8)

    # ---- pipeline: 4 stages x 8 layers == sequential reference ----
    mesh = jax.make_mesh((4, 2), ("pipe", "data"))
    L, B, D = 8, 8, 16
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, D, D), jnp.float32) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D), jnp.float32)

    def layer(wi, h):
        return jnp.tanh(h @ wi)

    ref = x
    for i in range(L):
        ref = layer(w[i], ref)

    cfg = PipelineConfig(n_stages=4, n_microbatches=4)
    out = jax.jit(lambda w, x: pipeline_apply(layer, w, x, mesh, cfg))(w, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print("pipeline OK")

    # ---- compressed pod gradient mean: unbiased across pods ----
    mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (64, 64),
                                jnp.float32)}
    ccfg = CompressionConfig(spec=GRAD_FP8)
    with mesh2:
        out = jax.jit(lambda g: pod_mean_grads(
            g, jax.random.PRNGKey(3), mesh2, ccfg))(g)
    err = float(jnp.max(jnp.abs(out["w"] - g["w"])))
    rel = err / float(jnp.std(g["w"]))
    assert rel < 0.2, rel
    print("compression OK", rel)
""")


@pytest.mark.slow
def test_pipeline_and_compression_multidevice(tmp_path):
    """Real multi-device semantics in a subprocess (8 forced host devices).

    Covers: GPipe pipeline == sequential reference; compressed inter-pod
    gradient mean stays within SR quantization noise of the exact mean."""
    script = tmp_path / "multidev.py"
    script.write_text(_MULTIDEV)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, str(script)], capture_output=True,
                       text=True, timeout=900, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "pipeline OK" in r.stdout
    assert "compression OK" in r.stdout
